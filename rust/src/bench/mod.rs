//! Benchmark harness — regenerates every table and figure of the paper's
//! evaluation section (DESIGN.md §3 experiment index).
//!
//! Speedups are reported two ways:
//! * **wall** — measured wall-clock on this CPU-PJRT substrate;
//! * **sim**  — the [`simclock`] cost model calibrated to the paper's
//!   memory-bound H100 regime (one target forward per verify round
//!   regardless of block width), which is the honest way to compare the
//!   *shape* of Table 1 against an 8B-class deployment.
//!
//! Each `table*` / `fig*` function prints a markdown table and appends it
//! to `results/<name>.md`.
//!
//! Serving-latency benchmarks (TTFT/TPOT percentiles under open-loop
//! load) live in [`serve`] and run against a live TCP server rather than
//! a bare engine; see BENCHMARKS.md for the full target index.

pub mod diff;
pub mod record;
pub mod serve;
pub mod simclock;

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context as _, Result};

use crate::datasets::{dataset, Example, Task};
use crate::engine::{DecodeEngine, GenParams, GenResult, SpecMethod};
use crate::eval;
use crate::spec::METHODS;
use crate::util::stats::Summary;
use crate::verify::VerifyPolicy;

/// Shared bench context.
pub struct BenchCtx<'a> {
    pub engine: &'a DecodeEngine,
    /// examples per task
    pub n: usize,
    pub seed: u64,
    pub max_new: usize,
    pub out_dir: PathBuf,
    /// where the machine-readable `BENCH_*.json` trajectories land
    /// (default: the working directory, where CI's smoke waves and the
    /// committed snapshots both expect them)
    pub bench_dir: PathBuf,
    /// cache of AR baseline runs keyed by (task, temp-milli, seed)
    baseline: std::cell::RefCell<BTreeMap<(Task, i64, u64), TaskEval>>,
}

impl<'a> BenchCtx<'a> {
    pub fn new(engine: &'a DecodeEngine, n: usize, seed: u64) -> Self {
        BenchCtx {
            engine,
            n,
            seed,
            max_new: 96,
            out_dir: PathBuf::from("results"),
            bench_dir: PathBuf::from("."),
            baseline: Default::default(),
        }
    }

    /// Bench-standard [`GenParams`] for one descriptor × policy × temp
    /// (the descriptor carries every drafting knob).
    pub fn params(
        &self,
        method: SpecMethod,
        policy: VerifyPolicy,
        temp: f32,
    ) -> GenParams {
        GenParams {
            method,
            policy,
            temperature: temp,
            max_new: self.max_new,
            seed: self.seed,
            probe: false,
            extract_every: 1,
            rounds_per_call: 1,
            cache: true,
        }
    }

    /// Run one method over one task's dataset.
    pub fn run_task(
        &self,
        task: Task,
        params: &GenParams,
    ) -> Result<TaskEval> {
        let examples = dataset(task, self.n, self.seed);
        let mut decode_s = Summary::new();
        let mut tok_s = Summary::new();
        let mut tau = Summary::new();
        let mut sim_units = Summary::new();
        let mut quality = QualityAgg::default();
        let mut relaxed = 0.0;
        for (i, ex) in examples.iter().enumerate() {
            let mut p = params.clone();
            p.seed = self.seed * 1000 + i as u64;
            let r = self.engine.generate(&ex.prompt, &p)?;
            decode_s.push(r.decode_seconds);
            if !r.tokens.is_empty() {
                tok_s.push(r.tokens.len() as f64 / r.decode_seconds.max(1e-9));
            }
            if params.method.is_speculative() {
                tau.push(r.tau());
            }
            sim_units.push(simclock::simulated_units(params.method, &r));
            relaxed += r.snapshot.relaxed_accepts;
            quality.add(ex, &r);
        }
        Ok(TaskEval {
            task,
            mean_decode_s: decode_s.mean(),
            mean_tok_per_s: tok_s.mean(),
            tau: tau.mean(),
            sim_units_per_tok: sim_units.mean(),
            quality: quality.finish(self.n),
            relaxed_total: relaxed,
        })
    }

    /// AR baseline for a task at a temperature (cached).
    pub fn baseline(&self, task: Task, temp: f32) -> Result<TaskEval> {
        let key = (task, (temp * 1000.0) as i64, self.seed);
        if let Some(b) = self.baseline.borrow().get(&key) {
            return Ok(b.clone());
        }
        let p = self.params(SpecMethod::Ar, VerifyPolicy::Strict, temp);
        let b = self.run_task(task, &p)?;
        self.baseline.borrow_mut().insert(key, b.clone());
        Ok(b)
    }

    /// Write a rendered table to results/<name>.md and stdout.
    pub fn emit(&self, name: &str, content: &str) -> Result<()> {
        println!("{content}");
        emit_md(&self.out_dir, name, content)?;
        Ok(())
    }

    /// Provenance block every measured run stamps on its record doc:
    /// `measured`, this host, the loaded artifact's layout hash, and the
    /// refresh command — overwriting whatever (possibly `estimated`)
    /// block the previous snapshot carried.
    pub fn record_env(&self, created_by: &str) -> record::Env {
        record::Env::measured(&self.engine.rt.layout().hash, created_by)
    }

    /// Write a schema-2 record doc to `bench_dir/BENCH_<target>.json`.
    pub fn emit_records(&self, doc: &record::RecordDoc) -> Result<()> {
        let path =
            self.bench_dir.join(format!("BENCH_{}.json", doc.target));
        record::write_doc(&path, doc)?;
        eprintln!("[written {}]", path.display());
        Ok(())
    }
}

/// Write one rendered markdown table to `<out_dir>/<name>.md`, creating
/// the directory if needed — the single emit path every bench target
/// (including [`serve`]) funnels through.
pub fn emit_md(out_dir: &Path, name: &str, content: &str) -> Result<PathBuf> {
    fs::create_dir_all(out_dir).with_context(|| {
        format!("creating results dir {}", out_dir.display())
    })?;
    let path = out_dir.join(format!("{name}.md"));
    fs::write(&path, content)
        .with_context(|| format!("writing {}", path.display()))?;
    eprintln!("[written {}]", path.display());
    Ok(path)
}

/// Per-(task, method) evaluation outcome.
#[derive(Debug, Clone)]
pub struct TaskEval {
    pub task: Task,
    pub mean_decode_s: f64,
    pub mean_tok_per_s: f64,
    pub tau: f64,
    pub sim_units_per_tok: f64,
    pub quality: Quality,
    pub relaxed_total: f64,
}

impl TaskEval {
    /// Wall-clock speedup vs a baseline eval (tokens/s ratio).
    pub fn speedup_wall(&self, base: &TaskEval) -> f64 {
        if base.mean_tok_per_s > 0.0 {
            self.mean_tok_per_s / base.mean_tok_per_s
        } else {
            0.0
        }
    }

    /// Simulated speedup under the memory-bound cost model.
    pub fn speedup_sim(&self, base: &TaskEval) -> f64 {
        if self.sim_units_per_tok > 0.0 {
            base.sim_units_per_tok / self.sim_units_per_tok
        } else {
            0.0
        }
    }
}

/// Quality metrics aggregated per task (which ones are meaningful depends
/// on the task; the tables pick the right column).
#[derive(Debug, Clone, Default)]
pub struct Quality {
    pub accuracy: f64,
    pub rouge_l: f64,
    pub bleu: f64,
    pub chrf: f64,
    pub judge: f64,
}

#[derive(Default)]
struct QualityAgg {
    correct: f64,
    rouge: f64,
    judge: f64,
    pairs: Vec<(String, String)>,
}

impl QualityAgg {
    fn add(&mut self, ex: &Example, r: &GenResult) {
        if eval::task_correct(ex, &r.text) {
            self.correct += 1.0;
        }
        self.rouge += eval::rouge_l(&r.text, &ex.reference);
        self.judge += eval::judge_score(ex, &r.text);
        self.pairs
            .push((r.text.trim().to_string(), ex.reference.trim().to_string()));
    }

    fn finish(self, n: usize) -> Quality {
        let n = n.max(1) as f64;
        Quality {
            accuracy: self.correct / n,
            rouge_l: self.rouge / n,
            bleu: eval::corpus_bleu(&self.pairs),
            chrf: eval::chrf::corpus_chrf(&self.pairs),
            judge: self.judge / n,
        }
    }
}

// ------------------------------------------------------------ tables -------

/// Method lineup of Table 1, straight from the descriptor registry
/// (every speculative family under strict verification), plus the MARS
/// row = the default tree descriptor + the margin-aware policy.
fn table1_rows() -> Vec<(&'static str, SpecMethod, VerifyPolicy)> {
    let mut rows: Vec<(&'static str, SpecMethod, VerifyPolicy)> = METHODS
        .iter()
        .filter(|m| m.default.is_speculative())
        .map(|m| (m.paper_label, m.default, VerifyPolicy::Strict))
        .collect();
    rows.push((
        "MARS",
        SpecMethod::default(),
        VerifyPolicy::Mars { theta: 0.9 },
    ));
    rows
}

/// Table 1: speedup + τ for every method × task at T = 1, K = 7, θ = 0.9.
pub fn table1(ctx: &BenchCtx) -> Result<()> {
    let temp = 1.0;
    let mut out = String::new();
    writeln!(out, "## Table 1 — overall performance (T=1, K=7, θ=0.9)\n")?;
    writeln!(
        out,
        "| Method | {} | Mean |",
        Task::all()
            .iter()
            .map(|t| format!("{} ↑spd/τ", t.paper_name()))
            .collect::<Vec<_>>()
            .join(" | ")
    )?;
    writeln!(
        out,
        "|---|{}---|",
        "---|".repeat(Task::all().len())
    )?;
    for (label, method, policy) in table1_rows() {
        let mut cells = Vec::new();
        let mut spd_acc = 0.0;
        let mut tau_acc = 0.0;
        for &task in Task::all() {
            let base = ctx.baseline(task, temp)?;
            let p = ctx.params(method, policy, temp);
            let e = ctx.run_task(task, &p)?;
            let spd = e.speedup_sim(&base);
            let w = e.speedup_wall(&base);
            cells.push(format!("{spd:.2}x ({w:.2}x) / {:.2}", e.tau));
            spd_acc += spd;
            tau_acc += e.tau;
        }
        let nt = Task::all().len() as f64;
        writeln!(
            out,
            "| {label} | {} | {:.2}x / {:.2} |",
            cells.join(" | "),
            spd_acc / nt,
            tau_acc / nt
        )?;
    }
    writeln!(
        out,
        "\nspeedup = simclock (wall-clock in parens); τ = tokens per \
         draft-verify cycle, ceiling K+1 = 8."
    )?;
    ctx.emit("table1", &out)?;
    Ok(())
}

/// Table 2: temperature × draft-length ablation on arith + code.
pub fn table2(ctx: &BenchCtx) -> Result<()> {
    let temps = [0.2f32, 0.6, 1.0];
    let ks = [6usize, 9, 12, 15];
    let mut out = String::new();
    writeln!(out, "## Table 2 — temperature & draft length K (MARS)\n")?;
    for task in [Task::Arith, Task::Code] {
        writeln!(out, "### {}\n", task.paper_name())?;
        writeln!(out, "| K | {} |", temps
            .iter()
            .map(|t| format!("T={t} spd/τ/acc"))
            .collect::<Vec<_>>()
            .join(" | "))?;
        writeln!(out, "|---|{}", "---|".repeat(temps.len()))?;
        // baseline row
        let mut brow = Vec::new();
        for &t in &temps {
            let b = ctx.baseline(task, t)?;
            brow.push(format!("1.00x / - / {:.3}", b.quality.accuracy));
        }
        writeln!(out, "| base | {} |", brow.join(" | "))?;
        for &k in &ks {
            let mut cells = Vec::new();
            for &t in &temps {
                let base = ctx.baseline(task, t)?;
                // chain method so K > 10 is exercised (tree depth caps at 10)
                let p = ctx.params(
                    SpecMethod::Sps { k },
                    VerifyPolicy::default(),
                    t,
                );
                let e = ctx.run_task(task, &p)?;
                cells.push(format!(
                    "{:.2}x / {:.2} / {:.3}",
                    e.speedup_sim(&base),
                    e.tau,
                    e.quality.accuracy
                ));
            }
            writeln!(out, "| {k} | {} |", cells.join(" | "))?;
        }
        writeln!(out)?;
    }
    ctx.emit("table2", &out)?;
    Ok(())
}

/// Table 3: ROUGE-L segment fidelity on the summarization task.
pub fn table3(ctx: &BenchCtx) -> Result<()> {
    let mut out = String::new();
    writeln!(out, "## Table 3 — ROUGE-L on CNN/DM* (θ=0.9, K=7, T=1)\n")?;
    writeln!(out, "| Method | ROUGE-L |")?;
    writeln!(out, "|---|---|")?;
    let base = ctx.baseline(Task::Sum, 1.0)?;
    writeln!(out, "| Baseline (AR) | {:.4} |", base.quality.rouge_l)?;
    for (label, method, policy) in [
        ("EAGLE-3", SpecMethod::default(), VerifyPolicy::Strict),
        (
            "MARS",
            SpecMethod::default(),
            VerifyPolicy::Mars { theta: 0.9 },
        ),
    ] {
        let e = ctx.run_task(Task::Sum, &ctx.params(method, policy, 1.0))?;
        writeln!(out, "| {label} | {:.4} |", e.quality.rouge_l)?;
    }
    ctx.emit("table3", &out)?;
    Ok(())
}

/// Table 4: BLEU / chrF on the MT task across θ.
pub fn table4(ctx: &BenchCtx) -> Result<()> {
    let thetas = [0.84f32, 0.86, 0.88, 0.90, 0.92, 0.94, 0.96, 0.98];
    let mut out = String::new();
    writeln!(out, "## Table 4 — WMT19* BLEU/chrF vs θ (K=7, T=1)\n")?;
    writeln!(out, "| Setting | BLEU | chrF | speedup(sim) |")?;
    writeln!(out, "|---|---|---|---|")?;
    let base = ctx.baseline(Task::Mt, 1.0)?;
    writeln!(
        out,
        "| Baseline | {:.2} | {:.2} | 1.00x |",
        base.quality.bleu, base.quality.chrf
    )?;
    let e3 = ctx.run_task(
        Task::Mt,
        &ctx.params(SpecMethod::default(), VerifyPolicy::Strict, 1.0),
    )?;
    writeln!(
        out,
        "| EAGLE-3 | {:.2} | {:.2} | {:.2}x |",
        e3.quality.bleu,
        e3.quality.chrf,
        e3.speedup_sim(&base)
    )?;
    for &th in &thetas {
        let p = ctx.params(
            SpecMethod::default(),
            VerifyPolicy::Mars { theta: th },
            1.0,
        );
        let e = ctx.run_task(Task::Mt, &p)?;
        writeln!(
            out,
            "| θ={th:.2} | {:.2} | {:.2} | {:.2}x |",
            e.quality.bleu,
            e.quality.chrf,
            e.speedup_sim(&base)
        )?;
    }
    ctx.emit("table4", &out)?;
    Ok(())
}

/// Table 5: MARS on vanilla SPD (framework-decoupled verification).
pub fn table5(ctx: &BenchCtx) -> Result<()> {
    let mut out = String::new();
    writeln!(out, "## Table 5 — MARS in standard SPD (T=1, γ=6)\n")?;
    writeln!(out, "| Task | Method | speedup(sim) | τ | quality |")?;
    writeln!(out, "|---|---|---|---|---|")?;
    for task in [Task::Arith, Task::Code, Task::Mt] {
        let base = ctx.baseline(task, 1.0)?;
        let q = |e: &TaskEval| match task {
            Task::Mt => format!("BLEU {:.2}", e.quality.bleu),
            _ => format!("acc {:.3}", e.quality.accuracy),
        };
        writeln!(
            out,
            "| {} | Baseline | 1.00x | - | {} |",
            task.paper_name(),
            q(&base)
        )?;
        for (label, policy) in [
            ("SPD", VerifyPolicy::Strict),
            ("SPD+MARS", VerifyPolicy::Mars { theta: 0.9 }),
        ] {
            let p = ctx.params(SpecMethod::Sps { k: 6 }, policy, 1.0);
            let e = ctx.run_task(task, &p)?;
            writeln!(
                out,
                "| {} | {label} | {:.2}x | {:.2} | {} |",
                task.paper_name(),
                e.speedup_sim(&base),
                e.tau,
                q(&e)
            )?;
        }
    }
    ctx.emit("table5", &out)?;
    Ok(())
}

/// Table 6: greedy decoding (T=0, K=7).
pub fn table6(ctx: &BenchCtx) -> Result<()> {
    let mut out = String::new();
    writeln!(out, "## Table 6 — greedy decoding (T=0, K=7)\n")?;
    writeln!(out, "| Task | Method | speedup(sim) | τ | acc |")?;
    writeln!(out, "|---|---|---|---|---|")?;
    for task in [Task::Arith, Task::Code] {
        let base = ctx.baseline(task, 0.0)?;
        writeln!(
            out,
            "| {} | Baseline | 1.00x | - | {:.3} |",
            task.paper_name(),
            base.quality.accuracy
        )?;
        for (label, policy) in [
            ("EAGLE-3", VerifyPolicy::Strict),
            ("MARS", VerifyPolicy::Mars { theta: 0.9 }),
        ] {
            let e = ctx
                .run_task(task, &ctx.params(SpecMethod::default(), policy, 0.0))?;
            writeln!(
                out,
                "| {} | {label} | {:.2}x | {:.2} | {:.3} |",
                task.paper_name(),
                e.speedup_sim(&base),
                e.tau,
                e.quality.accuracy
            )?;
        }
    }
    ctx.emit("table6", &out)?;
    Ok(())
}

/// Table 7: judge scores on the chat task (MT-Bench analog).
pub fn table7(ctx: &BenchCtx) -> Result<()> {
    let mut out = String::new();
    writeln!(out, "## Table 7 — chat quality, heuristic judge (T=1)\n")?;
    writeln!(out, "| Method | judge (0-10) | acc(keywords) |")?;
    writeln!(out, "|---|---|---|")?;
    let base = ctx.baseline(Task::Chat, 1.0)?;
    writeln!(
        out,
        "| Baseline | {:.2} | {:.3} |",
        base.quality.judge, base.quality.accuracy
    )?;
    for (label, policy) in [
        ("EAGLE-3", VerifyPolicy::Strict),
        ("MARS", VerifyPolicy::Mars { theta: 0.9 }),
    ] {
        let e = ctx.run_task(
            Task::Chat,
            &ctx.params(SpecMethod::default(), policy, 1.0),
        )?;
        writeln!(
            out,
            "| {label} | {:.2} | {:.3} |",
            e.quality.judge, e.quality.accuracy
        )?;
    }
    ctx.emit("table7", &out)?;
    Ok(())
}

/// Figure 3: θ sweep — accuracy + speedup, K ∈ {7, 10}.
pub fn fig3(ctx: &BenchCtx) -> Result<()> {
    let thetas = [0.84f32, 0.86, 0.88, 0.90, 0.92, 0.94, 0.96];
    let mut out = String::new();
    writeln!(out, "## Figure 3 — θ sweep (accuracy & speedup, T=1)\n")?;
    for task in [Task::Code, Task::Arith] {
        let base = ctx.baseline(task, 1.0)?;
        for k in [7usize, 10] {
            writeln!(out, "### {} K={k}\n", task.paper_name())?;
            writeln!(out, "| θ | speedup(sim) | accuracy |")?;
            writeln!(out, "|---|---|---|")?;
            for &th in &thetas {
                let p = ctx.params(
                    SpecMethod::default().with_overrides(Some(k), None, None),
                    VerifyPolicy::Mars { theta: th },
                    1.0,
                );
                let e = ctx.run_task(task, &p)?;
                writeln!(
                    out,
                    "| {th:.2} | {:.2}x | {:.3} |",
                    e.speedup_sim(&base),
                    e.quality.accuracy
                )?;
            }
            writeln!(out)?;
        }
    }
    ctx.emit("fig3", &out)?;
    Ok(())
}

/// Method × policy sweep: one row per [`SpecMethod`] × [`VerifyPolicy`]
/// combination — the two scenario axes the `spec` and `verify` subsystems
/// open up (`mars bench policies --methods sps:k=6,eagle_tree --policies
/// strict,mars:0.9`). Defaults sweep every speculative family in the
/// descriptor registry; nothing is hand-listed.
pub fn policy_sweep(
    ctx: &BenchCtx,
    methods: &[SpecMethod],
    policies: &[VerifyPolicy],
) -> Result<()> {
    let temp = 1.0;
    let tasks = [Task::Arith, Task::Code, Task::Mt];
    let mut out = String::new();
    writeln!(
        out,
        "## Method × policy sweep — drafting descriptors × verification \
         policies (T=1)\n"
    )?;
    writeln!(
        out,
        "| Method | Policy | {} |",
        tasks
            .iter()
            .map(|t| format!("{} spd/τ/acc/relaxed", t.paper_name()))
            .collect::<Vec<_>>()
            .join(" | ")
    )?;
    writeln!(out, "|---|---|{}", "---|".repeat(tasks.len()))?;
    let mut doc = record::RecordDoc::new(
        "policies",
        ctx.record_env("mars bench policies"),
    );
    doc.config_num("n", ctx.n as f64);
    doc.config_num("seed", ctx.seed as f64);
    doc.config_num("max_new", ctx.max_new as f64);
    for &method in methods {
        for &policy in policies {
            let mut cells = Vec::new();
            for &task in &tasks {
                let base = ctx.baseline(task, temp)?;
                let e =
                    ctx.run_task(task, &ctx.params(method, policy, temp))?;
                let keys = [
                    ("method", method.label()),
                    ("policy", policy.label()),
                    ("task", task.name().to_string()),
                ];
                let push = |d: &mut record::RecordDoc,
                            metric: &str,
                            value: f64,
                            unit: &str| {
                    d.push(metric, value, unit, ctx.n, ctx.seed, &keys);
                };
                push(&mut doc, "speedup_sim", e.speedup_sim(&base), "x");
                push(&mut doc, "tau", e.tau, "tok/cycle");
                push(&mut doc, "accuracy", e.quality.accuracy, "frac");
                push(&mut doc, "relaxed_total", e.relaxed_total, "tok");
                cells.push(format!(
                    "{:.2}x / {:.2} / {:.3} / {:.0}",
                    e.speedup_sim(&base),
                    e.tau,
                    e.quality.accuracy,
                    e.relaxed_total
                ));
            }
            // full labels, not family names: a sweep may carry several
            // descriptors of one family (sps:k=4 vs sps:k=12)
            writeln!(
                out,
                "| {} | {} | {} |",
                method.label(),
                policy.label(),
                cells.join(" | ")
            )?;
        }
    }
    writeln!(
        out,
        "\nStrict is the lossless floor (relaxed = 0 by construction); \
         every other policy row trades acceptance for quality per its own \
         knob, composed with every drafting method in the registry."
    )?;
    ctx.emit("policy_sweep", &out)?;
    ctx.emit_records(&doc)?;
    Ok(())
}

// ----------------------------------------------------- packing sweep -------

/// One (method, policy, pack) wave of [`packing`].
struct PackRow {
    method: SpecMethod,
    policy: VerifyPolicy,
    pack: usize,
    ok: usize,
    tok_per_s: f64,
    calls_per_tok: f64,
    tau: f64,
    ttft_ms: Summary,
    tpot_ms: Summary,
    /// relaxed accepts / all verify decisions over the wave (from the
    /// engine snapshots) — the acceptance-behavior record the packing
    /// equivalence pins ride on (DESIGN.md §12)
    relaxed_share: f64,
}

/// `mars bench packing` — the round-packing sweep (DESIGN.md §9.6):
/// `rounds_per_call` × method × policy, reporting tok/s, **device calls
/// per generated token** (the dispatch tax packing exists to amortize),
/// τ, and TTFT/TPOT percentiles. Renders `results/packing.md` and
/// refreshes the machine-readable `BENCH_packing.json` perf trajectory
/// so future PRs can diff the numbers.
pub fn packing(
    ctx: &BenchCtx,
    methods: &[SpecMethod],
    policies: &[VerifyPolicy],
    packs: &[usize],
) -> Result<()> {
    use crate::engine::SeqRunner;
    use std::time::Instant;
    if methods.is_empty() || policies.is_empty() || packs.is_empty() {
        anyhow::bail!("bench packing needs methods, policies and packs");
    }
    // Sum has the longest gold completions of the synthetic tasks, so
    // decodes run enough rounds for the dispatch amortization (the whole
    // point of the sweep) to show; short-answer tasks (arith) can finish
    // in 2-3 rounds, where a pack has nothing left to fuse.
    let task = Task::Sum;
    // the vs-pack=1 column (and the acceptance gate) divides by the
    // unpacked baseline — carry one even when --packs omitted it, and
    // say so rather than rendering a silent column of 0.00x
    let mut packs = packs.to_vec();
    if !packs.contains(&1) {
        println!("  note: adding the pack=1 baseline to the sweep");
        packs.insert(0, 1);
    }
    // clamp to the artifact's device bound and dedup: SeqRunner clamps
    // the same way, so a row keyed above pack_max would publish numbers
    // for a pack that never ran into the committed perf trajectory
    let pack_max = ctx
        .engine
        .rt
        .layout()
        .consts
        .get("pack_max")
        .copied()
        .unwrap_or(1)
        .max(1);
    let mut seen = std::collections::BTreeSet::new();
    let packs: Vec<usize> = packs
        .into_iter()
        .map(|p| {
            if p > pack_max {
                println!(
                    "  note: pack={p} clamped to device pack_max={pack_max}"
                );
            }
            p.min(pack_max)
        })
        .filter(|p| seen.insert(*p))
        .collect();
    let examples = dataset(task, ctx.n, ctx.seed);
    let mut rows: Vec<PackRow> = Vec::new();
    for &method in methods {
        for &policy in policies {
            for &pack in &packs {
                let mut row = PackRow {
                    method,
                    policy,
                    pack,
                    ok: 0,
                    tok_per_s: 0.0,
                    calls_per_tok: 0.0,
                    tau: 0.0,
                    ttft_ms: Summary::new(),
                    tpot_ms: Summary::new(),
                    relaxed_share: 0.0,
                };
                let mut tokens = 0usize;
                let mut calls = 0u64;
                let mut secs = 0.0;
                let mut tau = Summary::new();
                // (relaxed, all-decisions) across the wave's snapshots
                let mut decisions = (0.0f64, 0.0f64);
                for (i, ex) in examples.iter().enumerate() {
                    let mut p = ctx.params(method, policy, 1.0);
                    p.rounds_per_call = pack;
                    p.seed = ctx.seed * 1000 + i as u64;
                    let toks = crate::tokenizer::encode(&ex.prompt);
                    let t0 = Instant::now();
                    let mut runner =
                        SeqRunner::new(&ctx.engine.rt, &toks, &p, false)?;
                    let mut first: Option<Instant> = None;
                    let r = loop {
                        let done = runner.step()?;
                        if first.is_none() && runner.committed() > 0 {
                            first = Some(Instant::now());
                        }
                        if let Some(r) = done {
                            break r;
                        }
                    };
                    if r.tokens.is_empty() {
                        continue;
                    }
                    row.ok += 1;
                    let ttft = first
                        .map(|f| f.duration_since(t0).as_secs_f64())
                        .unwrap_or(0.0);
                    row.ttft_ms.push(ttft * 1e3);
                    if r.tokens.len() > 1 {
                        let span = r.prefill_seconds + r.decode_seconds;
                        let rest = (span - ttft).max(0.0);
                        row.tpot_ms
                            .push(rest * 1e3 / (r.tokens.len() - 1) as f64);
                    }
                    tokens += r.tokens.len();
                    calls += r.device_calls;
                    secs += r.decode_seconds;
                    if method.is_speculative() {
                        tau.push(r.tau());
                    }
                    decisions.0 += r.snapshot.relaxed_accepts;
                    decisions.1 += r.snapshot.exact_accepts
                        + r.snapshot.relaxed_accepts
                        + r.snapshot.rejects;
                }
                row.tok_per_s = tokens as f64 / secs.max(1e-9);
                row.calls_per_tok = calls as f64 / tokens.max(1) as f64;
                row.tau = tau.mean();
                row.relaxed_share = decisions.0 / decisions.1.max(1.0);
                println!(
                    "  {} / {} / pack={pack}: {:.2} calls/tok, {:.1} tok/s",
                    method.label(),
                    policy.label(),
                    row.calls_per_tok,
                    row.tok_per_s
                );
                rows.push(row);
            }
        }
    }

    // rendered table
    let mut out = String::new();
    writeln!(
        out,
        "## Round packing — device calls per generated token vs \
         rounds_per_call ({}, n={}, max_new={}, T=1)\n",
        task.paper_name(),
        ctx.n,
        ctx.max_new
    )?;
    writeln!(
        out,
        "| Method | Policy | pack | calls/tok | vs pack=1 | tok/s | τ | \
         TTFT p50 (ms) | TTFT p99 (ms) | TPOT p50 (ms) | TPOT p99 (ms) |"
    )?;
    writeln!(out, "|---|---|---|---|---|---|---|---|---|---|---|")?;
    for r in &rows {
        // the pack=1 row of the same method × policy is the baseline the
        // call-reduction column (and the acceptance gate) divides by
        let base = rows
            .iter()
            .find(|b| {
                b.method == r.method && b.policy == r.policy && b.pack == 1
            })
            .map(|b| b.calls_per_tok)
            .unwrap_or(0.0);
        let ratio = if r.calls_per_tok > 0.0 && base > 0.0 {
            base / r.calls_per_tok
        } else {
            0.0
        };
        writeln!(
            out,
            "| {} | {} | {} | {:.2} | {:.2}x | {:.1} | {:.2} | {:.0} | \
             {:.0} | {:.2} | {:.2} |",
            r.method.label(),
            r.policy.label(),
            r.pack,
            r.calls_per_tok,
            ratio,
            r.tok_per_s,
            r.tau,
            r.ttft_ms.p50(),
            r.ttft_ms.p99(),
            r.tpot_ms.p50(),
            r.tpot_ms.p99()
        )?;
    }
    writeln!(
        out,
        "\ncalls/tok counts every `execute_b` dispatch and buffer upload \
         the request issued (prefill included), divided by committed \
         tokens — the pure dispatch tax the paper's math never pays \
         (DESIGN.md §1.1: ~0.5 ms/call). `vs pack=1` is the reduction \
         against the same method × policy unpacked; packing leaves \
         tokens untouched (the equivalence pins), so tok/s gains are \
         dispatch savings only. TTFT stays flat by construction: the \
         first turn of every sequence runs unpacked."
    )?;
    ctx.emit("packing", &out)?;

    // machine-readable trajectory for PR-to-PR diffing (`bench diff`)
    let mut doc = record::RecordDoc::new(
        "packing",
        ctx.record_env("mars bench packing"),
    );
    doc.config_str("task", task.name());
    doc.config_num("n", ctx.n as f64);
    doc.config_num("seed", ctx.seed as f64);
    doc.config_num("max_new", ctx.max_new as f64);
    doc.config_num("pack_max", pack_max as f64);
    for r in &rows {
        let keys = [
            ("method", r.method.label()),
            ("policy", r.policy.label()),
            ("pack", r.pack.to_string()),
        ];
        let mut push = |metric: &str, value: f64, unit: &str| {
            doc.push(metric, value, unit, r.ok, ctx.seed, &keys);
        };
        push("device_calls_per_token", r.calls_per_tok, "calls/tok");
        push("tok_per_s", r.tok_per_s, "tok/s");
        push("tau", r.tau, "tok/cycle");
        push("relaxed_share", r.relaxed_share, "frac");
        push("ttft_ms_p50", r.ttft_ms.p50(), "ms");
        push("ttft_ms_p99", r.ttft_ms.p99(), "ms");
        push("tpot_ms_p50", r.tpot_ms.p50(), "ms");
        push("tpot_ms_p99", r.tpot_ms.p99(), "ms");
    }
    ctx.emit_records(&doc)?;
    Ok(())
}

// ------------------------------------------------------- batch sweep -------

/// One (method, policy, B) wave of [`batch`].
struct BatchWaveRow {
    method: SpecMethod,
    policy: VerifyPolicy,
    b: usize,
    ok: usize,
    tok_per_s: f64,
    /// amortized device dispatches per token (Σ `dispatch_share` /
    /// tokens): each shared dispatch contributes exactly 1 across its
    /// occupied lanes, so this is the replica-level dispatch tax
    calls_per_tok: f64,
    tau: f64,
    ttft_ms: Summary,
    tpot_ms: Summary,
    /// relaxed accepts / all verify decisions over the wave (DESIGN.md
    /// §12) — batching must not change acceptance behavior, and this
    /// record pins that PR-to-PR
    relaxed_share: f64,
}

/// `mars bench batch` — the cross-sequence batching sweep (DESIGN.md
/// §9.5): occupancy B × method × policy on the Sum task, every wave
/// keeping B lanes live in one [`crate::engine::BatchRunner`] (requests
/// join as lanes retire, continuous-batching style). Reports
/// tok/s/replica (total tokens over the wave's wall-clock — lanes
/// overlap, so per-lane decode seconds would double-count), amortized
/// **device dispatches per token**, τ, and TTFT/TPOT percentiles.
/// Renders `results/batch.md` and refreshes `BENCH_batch.json`.
pub fn batch(
    ctx: &BenchCtx,
    methods: &[SpecMethod],
    policies: &[VerifyPolicy],
    batches: &[usize],
) -> Result<()> {
    use crate::engine::BatchRunner;
    use std::time::Instant;
    if methods.is_empty() || policies.is_empty() || batches.is_empty() {
        anyhow::bail!("bench batch needs methods, policies and batches");
    }
    if !ctx.engine.rt.supports_batching() {
        anyhow::bail!(
            "artifacts lack the *_batch programs (recompile with \
             python/compile/aot.py)"
        );
    }
    // Sum runs enough rounds per request for occupancy amortization to
    // show (same reasoning as the packing sweep)
    let task = Task::Sum;
    // the vs B=1 column and the acceptance gate divide by the solo wave
    let mut batches = batches.to_vec();
    if !batches.contains(&1) {
        println!("  note: adding the B=1 baseline to the sweep");
        batches.insert(0, 1);
    }
    let batch_max = ctx.engine.rt.layout().batch_max().max(1);
    let mut seen = std::collections::BTreeSet::new();
    let batches: Vec<usize> = batches
        .into_iter()
        .map(|b| {
            if b > batch_max {
                println!(
                    "  note: B={b} clamped to device batch_max={batch_max}"
                );
            }
            b.min(batch_max)
        })
        .filter(|b| seen.insert(*b))
        .collect();
    let examples = dataset(task, ctx.n, ctx.seed);
    let mut rows: Vec<BatchWaveRow> = Vec::new();
    for &method in methods {
        for &policy in policies {
            for &b in &batches {
                let mut row = BatchWaveRow {
                    method,
                    policy,
                    b,
                    ok: 0,
                    tok_per_s: 0.0,
                    calls_per_tok: 0.0,
                    tau: 0.0,
                    ttft_ms: Summary::new(),
                    tpot_ms: Summary::new(),
                    relaxed_share: 0.0,
                };
                let mut decisions = (0.0f64, 0.0f64);
                let mut runner = BatchRunner::new(&ctx.engine.rt)?;
                let nmax = runner.batch_max();
                let mut admit_t: Vec<Option<Instant>> = vec![None; nmax];
                let mut first_t: Vec<Option<Instant>> = vec![None; nmax];
                let mut next = 0usize;
                let mut done = 0usize;
                let mut tokens = 0usize;
                let mut share = 0.0f64;
                let mut tau = Summary::new();
                let t0 = Instant::now();
                while done < examples.len() {
                    // keep B lanes live: admit as soon as a slot frees
                    while runner.occupancy() < b
                        && next < examples.len()
                        && runner.has_free_slot()
                    {
                        let mut p = ctx.params(method, policy, 1.0);
                        p.seed = ctx.seed * 1000 + next as u64;
                        let toks =
                            crate::tokenizer::encode(&examples[next].prompt);
                        let slot = runner.admit(&toks, &p, None)?;
                        admit_t[slot] = Some(Instant::now());
                        first_t[slot] = None;
                        next += 1;
                    }
                    for (slot, r) in runner.step()? {
                        done += 1;
                        let admitted =
                            admit_t[slot].take().expect("lane was admitted");
                        let first = first_t[slot]
                            .take()
                            .unwrap_or_else(Instant::now);
                        if r.tokens.is_empty() {
                            continue;
                        }
                        row.ok += 1;
                        let ttft =
                            first.duration_since(admitted).as_secs_f64();
                        row.ttft_ms.push(ttft * 1e3);
                        if r.tokens.len() > 1 {
                            let rest = first.elapsed().as_secs_f64();
                            row.tpot_ms
                                .push(rest * 1e3 / (r.tokens.len() - 1) as f64);
                        }
                        tokens += r.tokens.len();
                        share += r.dispatch_share;
                        if method.is_speculative() {
                            tau.push(r.tau());
                        }
                        decisions.0 += r.snapshot.relaxed_accepts;
                        decisions.1 += r.snapshot.exact_accepts
                            + r.snapshot.relaxed_accepts
                            + r.snapshot.rejects;
                    }
                    // stamp first-commit on the survivors
                    for slot in 0..nmax {
                        if admit_t[slot].is_some()
                            && first_t[slot].is_none()
                            && runner.committed(slot) > 0
                        {
                            first_t[slot] = Some(Instant::now());
                        }
                    }
                }
                let wall = t0.elapsed().as_secs_f64();
                row.tok_per_s = tokens as f64 / wall.max(1e-9);
                row.calls_per_tok = share / tokens.max(1) as f64;
                row.tau = tau.mean();
                row.relaxed_share = decisions.0 / decisions.1.max(1.0);
                println!(
                    "  {} / {} / B={b}: {:.2} calls/tok, {:.1} tok/s",
                    method.label(),
                    policy.label(),
                    row.calls_per_tok,
                    row.tok_per_s
                );
                rows.push(row);
            }
        }
    }

    // rendered table
    let mut out = String::new();
    writeln!(
        out,
        "## Cross-sequence batching — amortized dispatches per token vs \
         occupancy B ({}, n={}, max_new={}, T=1)\n",
        task.paper_name(),
        ctx.n,
        ctx.max_new
    )?;
    writeln!(
        out,
        "| Method | Policy | B | calls/tok | vs B=1 | tok/s/replica | τ | \
         TTFT p50 (ms) | TTFT p99 (ms) | TPOT p50 (ms) | TPOT p99 (ms) |"
    )?;
    writeln!(out, "|---|---|---|---|---|---|---|---|---|---|---|")?;
    for r in &rows {
        let base = rows
            .iter()
            .find(|x| {
                x.method == r.method && x.policy == r.policy && x.b == 1
            })
            .map(|x| x.calls_per_tok)
            .unwrap_or(0.0);
        let ratio = if r.calls_per_tok > 0.0 && base > 0.0 {
            base / r.calls_per_tok
        } else {
            0.0
        };
        writeln!(
            out,
            "| {} | {} | {} | {:.2} | {:.2}x | {:.1} | {:.2} | {:.0} | \
             {:.0} | {:.2} | {:.2} |",
            r.method.label(),
            r.policy.label(),
            r.b,
            r.calls_per_tok,
            ratio,
            r.tok_per_s,
            r.tau,
            r.ttft_ms.p50(),
            r.ttft_ms.p99(),
            r.tpot_ms.p50(),
            r.tpot_ms.p99()
        )?;
    }
    writeln!(
        out,
        "\ncalls/tok is the *amortized* dispatch count (Σ dispatch_share / \
         tokens): every shared round dispatch contributes exactly 1 \
         across its occupied lanes, prefill + join splices stay dedicated \
         — so B=4 should land near a quarter of B=1 plus the admission \
         tax. tok/s/replica divides total committed tokens by the wave's \
         wall-clock (lanes overlap; per-lane decode seconds would \
         double-count). Batched lanes commit the same tokens as solo runs \
         at T=0 (the equivalence pins in tests), so every gain is \
         dispatch amortization, not different decoding."
    )?;
    ctx.emit("batch", &out)?;

    // machine-readable trajectory for PR-to-PR diffing (`bench diff`)
    let mut doc =
        record::RecordDoc::new("batch", ctx.record_env("mars bench batch"));
    doc.config_str("task", task.name());
    doc.config_num("n", ctx.n as f64);
    doc.config_num("seed", ctx.seed as f64);
    doc.config_num("max_new", ctx.max_new as f64);
    doc.config_num("batch_max", batch_max as f64);
    for r in &rows {
        let keys = [
            ("method", r.method.label()),
            ("policy", r.policy.label()),
            ("batch", r.b.to_string()),
        ];
        let mut push = |metric: &str, value: f64, unit: &str| {
            doc.push(metric, value, unit, r.ok, ctx.seed, &keys);
        };
        push("dispatches_per_token", r.calls_per_tok, "calls/tok");
        push("tok_per_s_replica", r.tok_per_s, "tok/s");
        push("tau", r.tau, "tok/cycle");
        push("relaxed_share", r.relaxed_share, "frac");
        push("ttft_ms_p50", r.ttft_ms.p50(), "ms");
        push("ttft_ms_p99", r.ttft_ms.p99(), "ms");
        push("tpot_ms_p50", r.tpot_ms.p50(), "ms");
        push("tpot_ms_p99", r.tpot_ms.p99(), "ms");
    }
    ctx.emit_records(&doc)?;
    Ok(())
}

/// §Perf runtime ablation: resident-state vs hostloop, extract frequency.
pub fn perf(ctx: &BenchCtx, artifact_dir: &std::path::Path) -> Result<()> {
    use crate::runtime::Runtime;
    let mut out = String::new();
    writeln!(out, "## §Perf — runtime ablation (eagle_tree, MARS, T=1)\n")?;
    writeln!(out, "| runtime | tok/s | per-round device calls |")?;
    writeln!(out, "|---|---|---|")?;
    let examples = dataset(Task::Arith, ctx.n.min(8), ctx.seed);
    for (label, hostloop, every) in [
        ("hostloop (naive)", true, 1usize),
        ("resident state", false, 1),
        ("resident + extract/4", false, 4),
    ] {
        let rt = Runtime::new(artifact_dir)?;
        let mut engine = DecodeEngine::new(rt);
        engine.hostloop = hostloop;
        let mut toks = 0usize;
        let mut secs = 0.0;
        let mut calls = 0u64;
        let mut rounds = 0u64;
        for ex in &examples {
            let mut p =
                ctx.params(SpecMethod::default(), VerifyPolicy::default(), 1.0);
            p.extract_every = every;
            let r = engine.generate(&ex.prompt, &p)?;
            toks += r.tokens.len();
            secs += r.decode_seconds;
            calls += r.device_calls;
            rounds += r.snapshot.rounds as u64;
        }
        writeln!(
            out,
            "| {label} | {:.1} | {:.2} |",
            toks as f64 / secs.max(1e-9),
            calls as f64 / rounds.max(1) as f64
        )?;
    }
    ctx.emit("perf", &out)?;
    Ok(())
}
