//! Perturbation tests: each hand-mirrored surface gets a fixture with
//! one injected drift, and the checker must fail naming the offending
//! key. The clean-tree integration test lives in
//! `rust/tests/contracts.rs`.

use super::*;

/// A minimal but well-formed manifest covering every surface.
fn mini_manifest() -> ContractManifest {
    let text = r#"{
        "schema": 1,
        "hash": "feedfacefeedface",
        "layout": {
            "scalars": {"pos": 0, "out_len": 1, "temp": 2, "kdraft": 3},
            "cfg": {"temp": 0, "kdraft": 1},
            "consts": {
                "pack_max": 4, "batch_max": 8, "k_max": 8, "n_cfg": 2,
                "probe_max": 4, "probe_w": 8, "p_max": 64, "out_max": 64,
                "s_max": 128, "vocab": 100
            }
        },
        "policies": {"strict": 0.0, "mars": 1.0},
        "executables": {
            "ar_step": {"stateless": false, "batched": false,
                        "weight_families": ["target"]},
            "sps_round": {"stateless": false, "batched": false,
                          "weight_families": ["target", "sps"]}
        }
    }"#;
    ContractManifest::parse(text).unwrap()
}

const CLEAN_STATE: &str = r#"
    pub const REQUIRED_SCALARS: &[&str] = &["pos", "out_len"];
    pub const RESUME_RESET_SCALARS: &[&str] = &["out_len"];
"#;

const CLEAN_VERIFY: &str = "
    pub const POLICY_ID_STRICT: f32 = 0.0;
    pub const POLICY_ID_MARS: f32 = 1.0;
";

const CLEAN_SPEC: &str = r#"
    fn exec_name(&self) -> &'static str {
        match self { M::Ar => "ar_step", M::Sps => "sps_round" }
    }
    fn multi_exec_name(&self) -> &'static str { "ar_step" }
    fn batch_exec_name(&self) -> &'static str { "ar_step" }
    fn batch_multi_exec_name(&self) -> &'static str { "sps_round" }
"#;

const CLEAN_RUNTIME: &str = r#"
    pub fn encode_cfg(lay: &Layout) -> Vec<f32> {
        c("temp");
        c("kdraft");
        let _ = lay.cfg.get("kdraft");
        out
    }
    fn kick(&self) { self.run("ar_step").unwrap(); }
"#;

const CLEAN_ENGINE: &str = r#"
    let cap = rt.layout().consts.get("pack_max").copied().unwrap_or(1);
    let _ = rt.has_exec("sps_round");
"#;

const CLEAN_REQUEST: &str = r#"
    let id = v.get("id");
    o.set("tau", Value::Num(1.0));
"#;

const CLEAN_SERVER: &str = r#"//! Protocol: requests carry "id" and
//! responses carry "tau" per line.
fn serve() {}
"#;

fn mini_sources() -> Sources {
    Sources {
        state: CLEAN_STATE.into(),
        verify: CLEAN_VERIFY.into(),
        spec: CLEAN_SPEC.into(),
        runtime: CLEAN_RUNTIME.into(),
        engine: CLEAN_ENGINE.into(),
        replica: String::new(),
        request: CLEAN_REQUEST.into(),
        server: CLEAN_SERVER.into(),
    }
}

fn keys(drifts: &[Drift]) -> Vec<&str> {
    drifts.iter().map(|d| d.key.as_str()).collect()
}

#[test]
fn clean_fixtures_pass_every_surface() {
    let m = mini_manifest();
    let s = mini_sources();
    let report = run_all(
        &m,
        &s,
        Some(&crate::bench::diff::thresholds_markdown()),
    );
    assert!(report.ok(), "unexpected drifts:\n{}", report.render());
    assert_eq!(report.surfaces.len(), 7);
}

#[test]
fn perturbed_scalar_slot_names_the_slot() {
    // rust grows a scalar the manifest doesn't have (a python-side
    // rename would look identical from this end)
    let m = mini_manifest();
    let state = CLEAN_STATE
        .replace(r#""pos", "out_len""#, r#""pos", "out_len", "acc_ema""#);
    let drifts = check_state_scalars(&m, &state);
    assert!(keys(&drifts).contains(&"acc_ema"), "{drifts:?}");
}

#[test]
fn perturbed_policy_id_names_the_policy() {
    let m = mini_manifest();
    // value drift
    let verify =
        CLEAN_VERIFY.replace("MARS: f32 = 1.0", "MARS: f32 = 5.0");
    let drifts = check_policies(&m, &verify);
    assert!(keys(&drifts).contains(&"mars"), "{drifts:?}");
    // missing-constant drift
    let verify = CLEAN_VERIFY.replace(
        "pub const POLICY_ID_MARS: f32 = 1.0;",
        "",
    );
    let drifts = check_policies(&m, &verify);
    assert!(keys(&drifts).contains(&"mars"), "{drifts:?}");
}

#[test]
fn perturbed_exec_name_names_the_exec() {
    let m = mini_manifest();
    // rust dispatches a name the registry doesn't know (soundness)
    let spec = CLEAN_SPEC.replace("\"sps_round\" }", "\"sps_round_v2\" }");
    let drifts = check_exec_names(
        &m,
        &spec,
        &[("runtime", CLEAN_RUNTIME), ("engine", CLEAN_ENGINE)],
    );
    assert!(keys(&drifts).contains(&"sps_round_v2"), "{drifts:?}");
}

#[test]
fn unreferenced_exec_names_the_exec() {
    // the registry grows a program nothing in rust dispatches
    // (completeness)
    let mut m = mini_manifest();
    m.executables.insert(
        "ghost_round".into(),
        manifest::ExecEntry {
            stateless: false,
            batched: false,
            weight_families: vec!["target".into()],
        },
    );
    let drifts = check_exec_names(
        &m,
        CLEAN_SPEC,
        &[("runtime", CLEAN_RUNTIME), ("engine", CLEAN_ENGINE)],
    );
    assert!(keys(&drifts).contains(&"ghost_round"), "{drifts:?}");
}

#[test]
fn perturbed_wire_field_names_the_field() {
    // request.rs reads a field the server protocol doc never mentions
    let request = format!(
        "{CLEAN_REQUEST}\n    let extra = v.get(\"cached_tokens\");\n"
    );
    let drifts = check_wire_fields(&request, CLEAN_SERVER);
    assert!(keys(&drifts).contains(&"cached_tokens"), "{drifts:?}");
    // and the doc fix clears it
    let server = CLEAN_SERVER
        .replace("\"tau\" per line.", "\"tau\", \"cached_tokens\".");
    assert!(check_wire_fields(&request, &server).is_empty());
}

#[test]
fn cfg_slot_without_scalar_twin_is_named() {
    let mut m = mini_manifest();
    m.cfg.insert("orphan_cfg".into(), 1);
    let drifts = check_cfg(&m, CLEAN_RUNTIME);
    assert!(keys(&drifts).contains(&"orphan_cfg"), "{drifts:?}");
}

#[test]
fn cfg_vector_unknown_name_is_named() {
    let m = mini_manifest();
    let runtime = CLEAN_RUNTIME.replace("c(\"kdraft\")", "c(\"krafted\")");
    let drifts = check_cfg(&m, &runtime);
    assert!(keys(&drifts).contains(&"krafted"), "{drifts:?}");
}

#[test]
fn missing_required_const_is_named() {
    let mut m = mini_manifest();
    m.consts.remove("pack_max");
    let drifts = check_consts(&m, &[("engine", CLEAN_ENGINE)]);
    assert!(keys(&drifts).contains(&"pack_max"), "{drifts:?}");
}

#[test]
fn engine_without_pack_clamp_is_named() {
    let m = mini_manifest();
    let engine = CLEAN_ENGINE.replace("pack_max", "hack_max");
    let drifts = check_consts(&m, &[("engine", &engine)]);
    // both the unknown-const read and the missing-clamp checks fire
    let k = keys(&drifts);
    assert!(k.contains(&"hack_max") && k.contains(&"pack_max"), "{drifts:?}");
}

#[test]
fn thresholds_drift_is_reported() {
    assert_eq!(check_thresholds("no table here").len(), 1);
    let doc = format!(
        "intro\n\n{}\ntail",
        crate::bench::diff::thresholds_markdown()
    );
    assert!(check_thresholds(&doc).is_empty());
}

#[test]
fn report_renders_keys_and_summary() {
    let report = CheckReport {
        drifts: vec![Drift::new(
            "policy-ids",
            "mars",
            "rust id 5 != manifest id 1".into(),
        )],
        surfaces: vec!["policy-ids"],
    };
    assert!(!report.ok());
    let text = report.render();
    assert!(text.contains("DRIFT [policy-ids] mars"));
    assert!(text.contains("1 surfaces checked, 1 drift(s)"));
}
