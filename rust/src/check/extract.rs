//! Lightweight line/region-level extraction from rust sources — no
//! syn/proc-macro machinery. The mirrors the contract checker cares
//! about are all simple, stylized surfaces (string-list consts, `f32`
//! id consts, match arms returning string literals, `.get("...")` /
//! `.set("...")` call sites), so plain text scanning is both sufficient
//! and robust against formatting churn (`cargo fmt` output is stable).

/// Cut the source at its `#[cfg(test)]` module: contract surfaces live
/// in non-test code, and test fixtures would otherwise contribute
/// false positives.
pub fn strip_tests(src: &str) -> &str {
    match src.find("#[cfg(test)]") {
        Some(pos) => &src[..pos],
        None => src,
    }
}

/// The module doc block: every `//!` line, joined. (The wire-protocol
/// doc in `coordinator/server.rs` is one of the checked surfaces.)
pub fn module_doc(src: &str) -> String {
    src.lines()
        .map(str::trim_start)
        .filter(|l| l.starts_with("//!"))
        .collect::<Vec<_>>()
        .join("\n")
}

/// Every string literal in `text`, in order. Handles `\"` escapes; the
/// surfaces scanned here contain no raw strings outside tests.
pub fn quoted(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut chars = text.chars().peekable();
    while let Some(c) = chars.next() {
        if c != '"' {
            continue;
        }
        let mut lit = String::new();
        loop {
            match chars.next() {
                None => return out, // unterminated: ignore the tail
                Some('\\') => {
                    // keep the escaped char verbatim; contract names
                    // never contain escapes, so fidelity is moot
                    if let Some(e) = chars.next() {
                        lit.push(e);
                    }
                }
                Some('"') => break,
                Some(ch) => lit.push(ch),
            }
        }
        out.push(lit);
    }
    out
}

/// The string items of `pub const NAME: &[&str] = &[ ... ];`.
pub fn str_list_const(src: &str, name: &str) -> Option<Vec<String>> {
    let start = src.find(&format!("const {name}:"))?;
    let rest = &src[start..];
    let end = rest.find("];")?;
    Some(quoted(&rest[..end]))
}

/// `pub const <PREFIX><NAME>: f32 = <value>;` lines → (NAME, value).
pub fn f32_consts(src: &str, prefix: &str) -> Vec<(String, f64)> {
    let needle = format!("pub const {prefix}");
    let mut out = Vec::new();
    for line in src.lines() {
        let line = line.trim();
        let Some(rest) = line.strip_prefix(&needle) else { continue };
        // rest is e.g. `STRICT: f32 = 0.0;`
        let Some((name, tail)) = rest.split_once(':') else { continue };
        let Some((_, val)) = tail.split_once('=') else { continue };
        let val = val.trim().trim_end_matches(';').trim();
        if let Ok(v) = val.parse::<f64>() {
            out.push((name.trim().to_string(), v));
        }
    }
    out
}

/// The body of `fn <name>(...) { ... }` — brace-matched from the first
/// `{` after the signature, skipping braces inside string/char literals
/// and `//` comments. A `pub fn <name>(` match wins over a plain
/// `fn <name>(` one: trait declarations and private impls of the same
/// name (e.g. `DraftSource::exec_name`) precede the public inherent
/// method that actually carries the contract surface.
pub fn fn_body<'a>(src: &'a str, name: &str) -> Option<&'a str> {
    let sig = src
        .find(&format!("pub fn {name}("))
        .or_else(|| src.find(&format!("fn {name}(")))?;
    let rest = &src[sig..];
    let open = rest.find('{')?;
    let body = &rest[open..];
    let bytes = body.as_bytes();
    let mut depth = 0usize;
    let mut i = 0usize;
    while i < bytes.len() {
        match bytes[i] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return Some(&body[..=i]);
                }
            }
            b'"' => {
                i += 1;
                while i < bytes.len() && bytes[i] != b'"' {
                    if bytes[i] == b'\\' {
                        i += 1;
                    }
                    i += 1;
                }
            }
            b'\'' => {
                // char literal ('{' or '\x') vs lifetime ('a): a literal
                // closes within 4 bytes; lifetimes have no closing quote
                if i + 2 < bytes.len() && bytes[i + 2] == b'\'' {
                    i += 2;
                } else if i + 3 < bytes.len()
                    && bytes[i + 1] == b'\\'
                    && bytes[i + 3] == b'\''
                {
                    i += 3;
                }
            }
            b'/' => {
                if i + 1 < bytes.len() && bytes[i + 1] == b'/' {
                    while i < bytes.len() && bytes[i] != b'\n' {
                        i += 1;
                    }
                }
            }
            _ => {}
        }
        i += 1;
    }
    None
}

/// String literals passed to any of `callees` — occurrences of
/// `<callee>("<lit>"` anywhere in `src` (e.g. `run`, `has_exec`,
/// `konst`, `.get`, `.set`).
pub fn called_with_str(src: &str, callees: &[&str]) -> Vec<String> {
    let mut out = Vec::new();
    for callee in callees {
        let needle = format!("{callee}(");
        let mut at = 0usize;
        while let Some(pos) = src[at..].find(&needle) {
            let after = at + pos + needle.len();
            // tolerate rustfmt line breaks between `(` and the literal
            let arg = src[after..].trim_start();
            if let Some(lit) = arg.strip_prefix('"') {
                if let Some(end) = lit.find('"') {
                    out.push(lit[..end].to_string());
                }
            }
            at = after;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quoted_extracts_in_order() {
        assert_eq!(
            quoted(r#"a "one" b "two" c"#),
            vec!["one".to_string(), "two".to_string()]
        );
        assert_eq!(quoted(r#""es\"caped""#), vec!["es\"caped".to_string()]);
        assert_eq!(quoted("none here"), Vec::<String>::new());
    }

    #[test]
    fn str_list_const_reads_the_items() {
        let src = r#"
            pub const NAMES: &[&str] = &[
                "pos", "out_len",
                "seed",
            ];
            pub const OTHER: &[&str] = &["x"];
        "#;
        assert_eq!(
            str_list_const(src, "NAMES").unwrap(),
            vec!["pos", "out_len", "seed"]
        );
        assert_eq!(str_list_const(src, "OTHER").unwrap(), vec!["x"]);
        assert!(str_list_const(src, "MISSING").is_none());
    }

    #[test]
    fn f32_consts_parse_name_and_value() {
        let src = "
            pub const POLICY_ID_STRICT: f32 = 0.0;
            pub const POLICY_ID_MARS: f32 = 1.0;
            const UNRELATED: usize = 4;
        ";
        let got = f32_consts(src, "POLICY_ID_");
        assert_eq!(
            got,
            vec![("STRICT".to_string(), 0.0), ("MARS".to_string(), 1.0)]
        );
    }

    #[test]
    fn fn_body_brace_matches() {
        let src = r#"
            fn outer() { inner(); }
            fn target(x: usize) -> &'static str {
                if x > 0 { "deep" } else { "other" }
            }
        "#;
        let body = fn_body(src, "target").unwrap();
        assert!(body.contains("deep") && body.contains("other"));
        assert!(!body.contains("inner"));
        assert!(fn_body(src, "missing").is_none());
    }

    #[test]
    fn fn_body_requires_exact_name() {
        let src = "
            fn multi_exec_name() { a(\"multi\"); }
            fn exec_name() { b(\"solo\"); }
        ";
        let body = fn_body(src, "exec_name").unwrap();
        assert!(body.contains("solo") && !body.contains("multi"));
    }

    #[test]
    fn called_with_str_finds_call_sites() {
        let src = r#"
            self.run("prefill", None)?;
            rt.has_exec("batch_join");
            let x = other("not_this");
        "#;
        let mut got = called_with_str(src, &["run", "has_exec"]);
        got.sort();
        assert_eq!(got, vec!["batch_join", "prefill"]);
    }

    #[test]
    fn strip_tests_cuts_the_module() {
        let src = "real();\n#[cfg(test)]\nmod tests { fake(); }";
        assert!(!strip_tests(src).contains("fake"));
    }

    #[test]
    fn module_doc_collects_bang_lines() {
        let src = "//! line one\n//! `\"field\"` two\nuse std::fmt;\n";
        let doc = module_doc(src);
        assert!(doc.contains("line one") && doc.contains("\"field\""));
        assert!(!doc.contains("std::fmt"));
    }
}
