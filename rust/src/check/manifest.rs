//! Parsed form of `contracts.json` — the machine-readable contract
//! manifest exported by `python/compile/state_spec.py::contracts_json`
//! (via `python -m compile.contracts` or as a side effect of
//! `compile.aot`). See DESIGN.md §11.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Value;

/// One executable of the registry (`compile/exec_registry.py`).
#[derive(Debug, Clone)]
pub struct ExecEntry {
    /// Lowered without a leading flat-state argument (`prefill`).
    pub stateless: bool,
    /// Leading state is the `BATCH_MAX`-stacked vector (§9.5).
    pub batched: bool,
    /// Weight-family parameter pytrees appended after state+extras.
    pub weight_families: Vec<String>,
}

/// The whole contract manifest.
#[derive(Debug, Clone)]
pub struct ContractManifest {
    /// Scalar slot name → index (`state_spec.SCALARS`).
    pub scalars: BTreeMap<String, usize>,
    /// Prefill cfg-vector name → index (`state_spec.CFG`).
    pub cfg: BTreeMap<String, usize>,
    /// Layout constants (`pack_max`, `batch_max`, `k_max`, `n_cfg`, ...).
    pub consts: BTreeMap<String, usize>,
    /// Verification-policy name → device id (`POLICY_*`).
    pub policies: BTreeMap<String, f64>,
    /// Exec-name registry with per-executable flags.
    pub executables: BTreeMap<String, ExecEntry>,
    /// The embedded full layout document (consumable by
    /// [`crate::runtime::state::Layout::from_json`]).
    pub layout_doc: Value,
    /// Manifest self-hash (python-side, sha256[:16] of the document).
    pub hash: String,
}

impl ContractManifest {
    /// Parse the manifest from its JSON text.
    pub fn parse(text: &str) -> Result<ContractManifest> {
        let doc = Value::parse(text)
            .map_err(|e| anyhow!("contracts.json: bad json: {e}"))?;
        Self::from_json(&doc)
    }

    /// Parse the manifest from a parsed JSON document.
    pub fn from_json(doc: &Value) -> Result<ContractManifest> {
        let schema = doc
            .get("schema")
            .and_then(|s| s.as_usize())
            .context("contracts.json: missing schema")?;
        if schema != 1 {
            anyhow::bail!("contracts.json: unsupported schema {schema}");
        }
        let layout_doc = doc
            .get("layout")
            .context("contracts.json: missing layout")?
            .clone();
        let index_map = |v: &Value, key: &str| -> Result<BTreeMap<String, usize>> {
            let obj = v
                .get(key)
                .and_then(|x| x.as_obj())
                .with_context(|| format!("contracts.json: layout.{key}"))?;
            obj.iter()
                .map(|(k, x)| {
                    x.as_usize()
                        .map(|n| (k.clone(), n))
                        .with_context(|| format!("layout.{key}.{k}"))
                })
                .collect()
        };
        let mut policies = BTreeMap::new();
        for (k, v) in doc
            .get("policies")
            .and_then(|p| p.as_obj())
            .context("contracts.json: missing policies")?
        {
            policies.insert(
                k.clone(),
                v.as_f64().with_context(|| format!("policies.{k}"))?,
            );
        }
        let mut executables = BTreeMap::new();
        for (name, e) in doc
            .get("executables")
            .and_then(|x| x.as_obj())
            .context("contracts.json: missing executables")?
        {
            let flag = |key: &str| -> Result<bool> {
                e.get(key)
                    .and_then(|b| b.as_bool())
                    .with_context(|| format!("executables.{name}.{key}"))
            };
            let fams = e
                .get("weight_families")
                .and_then(|f| f.as_arr())
                .with_context(|| {
                    format!("executables.{name}.weight_families")
                })?
                .iter()
                .map(|f| f.as_str().unwrap_or("").to_string())
                .collect();
            executables.insert(
                name.clone(),
                ExecEntry {
                    stateless: flag("stateless")?,
                    batched: flag("batched")?,
                    weight_families: fams,
                },
            );
        }
        Ok(ContractManifest {
            scalars: index_map(&layout_doc, "scalars")?,
            cfg: index_map(&layout_doc, "cfg")?,
            consts: index_map(&layout_doc, "consts")?,
            policies,
            executables,
            layout_doc,
            hash: doc
                .get("hash")
                .and_then(|h| h.as_str())
                .unwrap_or("")
                .to_string(),
        })
    }

    /// Load from a file path.
    pub fn load(path: &Path) -> Result<ContractManifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
            .with_context(|| format!("parsing {}", path.display()))
    }
}
