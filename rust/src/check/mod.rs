//! Cross-layer contract checker (`mars check contracts`, DESIGN.md §11).
//!
//! The stack has three hand-mirrored contract surfaces: the python↔rust
//! flat-state ABI (`python/compile/state_spec.py` ↔
//! `runtime/state.rs` / `verify/mod.rs` / the exec-name tables in
//! `spec/mod.rs`), the wire protocol (`coordinator/request.rs` fields ↔
//! the `coordinator/server.rs` protocol doc), and the bench gate
//! (`bench/diff.rs` threshold table ↔ BENCHMARKS.md). The layout hash
//! guards slot *indices* only; everything else used to be convention.
//!
//! This module machine-checks all of it: the python side exports a
//! contract manifest (`artifacts/contracts.json`, see
//! `compile/contracts.py`), and [`run_all`] diffs that manifest against
//! the rust sources using lightweight text extraction
//! ([`extract`] — no proc-macro machinery). Every drift is reported
//! with the offending key named; `mars check contracts` exits nonzero
//! on any drift. A committed manifest fixture
//! (`rust/tests/fixtures/contracts.json`, freshness-pinned by the
//! python suite) lets the checker and the integration tests run
//! without a python toolchain.

pub mod extract;
pub mod manifest;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use manifest::ContractManifest;

/// One detected contract drift: which surface, which key, and what
/// exactly disagrees.
#[derive(Debug, Clone)]
pub struct Drift {
    /// The checked surface (e.g. `"state-scalars"`, `"wire-fields"`).
    pub surface: &'static str,
    /// The offending key (scalar/policy/exec/field/const name).
    pub key: String,
    /// Human-readable disagreement.
    pub detail: String,
}

impl Drift {
    fn new(surface: &'static str, key: &str, detail: String) -> Drift {
        Drift { surface, key: key.to_string(), detail }
    }
}

/// Outcome of a full checker run.
#[derive(Debug, Clone, Default)]
pub struct CheckReport {
    /// Every drift found, in surface order.
    pub drifts: Vec<Drift>,
    /// Surfaces that ran (for the summary line).
    pub surfaces: Vec<&'static str>,
}

impl CheckReport {
    /// Did every surface hold?
    pub fn ok(&self) -> bool {
        self.drifts.is_empty()
    }

    /// Render the report: one line per drift, then a summary.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for d in &self.drifts {
            let _ = writeln!(
                out,
                "DRIFT [{}] {}: {}",
                d.surface, d.key, d.detail
            );
        }
        let _ = writeln!(
            out,
            "{} surfaces checked, {} drift(s)",
            self.surfaces.len(),
            self.drifts.len()
        );
        out
    }
}

/// The rust sources the checker extracts from, loaded as text.
pub struct Sources {
    /// `runtime/state.rs` — `REQUIRED_SCALARS`, `RESUME_RESET_SCALARS`.
    pub state: String,
    /// `verify/mod.rs` — the `POLICY_ID_*` constants.
    pub verify: String,
    /// `spec/mod.rs` — the exec-name tables.
    pub spec: String,
    /// `runtime/mod.rs` — pinned exec names, `cfg_vector`, consts.
    pub runtime: String,
    /// `engine/mod.rs` — the `pack_max` clamp, batch exec dispatch.
    pub engine: String,
    /// `coordinator/replica.rs` — server-side exec/const references.
    pub replica: String,
    /// `coordinator/request.rs` — the wire field codec.
    pub request: String,
    /// `coordinator/server.rs` — the wire protocol doc.
    pub server: String,
}

impl Sources {
    /// Load every checked source under `src_root` (`rust/src`).
    pub fn load(src_root: &Path) -> Result<Sources> {
        let read = |rel: &str| -> Result<String> {
            let path = src_root.join(rel);
            std::fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))
        };
        Ok(Sources {
            state: read("runtime/state.rs")?,
            verify: read("verify/mod.rs")?,
            spec: read("spec/mod.rs")?,
            runtime: read("runtime/mod.rs")?,
            engine: read("engine/mod.rs")?,
            replica: read("coordinator/replica.rs")?,
            request: read("coordinator/request.rs")?,
            server: read("coordinator/server.rs")?,
        })
    }
}

/// Layout consts the rust side reads by name — all must be exported.
const REQUIRED_CONSTS: &[&str] = &[
    "pack_max", "batch_max", "k_max", "n_cfg", "probe_max", "probe_w",
    "p_max", "out_max", "s_max", "vocab",
];

/// `state.rs` scalar-name lists vs the manifest's scalar table.
pub fn check_state_scalars(
    m: &ContractManifest,
    state_src: &str,
) -> Vec<Drift> {
    let mut drifts = Vec::new();
    for list in ["REQUIRED_SCALARS", "RESUME_RESET_SCALARS"] {
        match extract::str_list_const(state_src, list) {
            None => drifts.push(Drift::new(
                "state-scalars",
                list,
                "const not found in runtime/state.rs".into(),
            )),
            Some(names) => {
                for name in names {
                    if !m.scalars.contains_key(&name) {
                        drifts.push(Drift::new(
                            "state-scalars",
                            &name,
                            format!(
                                "{list} lists '{name}' but the manifest \
                                 has no such scalar slot"
                            ),
                        ));
                    }
                }
            }
        }
    }
    drifts
}

/// Cfg-table invariants: every cfg slot has a same-named scalar twin
/// (the device prefill and `restamp_resumed` copy cfg→scalar by name),
/// cfg indices fit `n_cfg`, and every name `encode_cfg` writes or reads
/// is a known cfg slot or const.
pub fn check_cfg(m: &ContractManifest, runtime_src: &str) -> Vec<Drift> {
    let mut drifts = Vec::new();
    for (name, &idx) in &m.cfg {
        if !m.scalars.contains_key(name) {
            drifts.push(Drift::new(
                "cfg-slots",
                name,
                format!(
                    "cfg slot '{name}' has no scalar twin — \
                     restamp_resumed would misaddress it"
                ),
            ));
        }
        if let Some(&n_cfg) = m.consts.get("n_cfg") {
            if idx >= n_cfg {
                drifts.push(Drift::new(
                    "cfg-slots",
                    name,
                    format!("cfg index {idx} >= n_cfg {n_cfg}"),
                ));
            }
        }
    }
    match extract::fn_body(extract::strip_tests(runtime_src), "encode_cfg") {
        None => drifts.push(Drift::new(
            "cfg-slots",
            "encode_cfg",
            "fn encode_cfg not found in runtime/mod.rs".into(),
        )),
        Some(body) => {
            for name in
                extract::called_with_str(body, &["c", "konst", ".get"])
            {
                if !m.cfg.contains_key(&name)
                    && !m.consts.contains_key(&name)
                {
                    drifts.push(Drift::new(
                        "cfg-slots",
                        &name,
                        format!(
                            "encode_cfg references '{name}' — neither a \
                             manifest cfg slot nor a const"
                        ),
                    ));
                }
            }
        }
    }
    drifts
}

/// `POLICY_ID_*` constants vs the manifest's policy-id table, both
/// directions.
pub fn check_policies(
    m: &ContractManifest,
    verify_src: &str,
) -> Vec<Drift> {
    let mut drifts = Vec::new();
    let consts =
        extract::f32_consts(extract::strip_tests(verify_src), "POLICY_ID_");
    if consts.is_empty() {
        drifts.push(Drift::new(
            "policy-ids",
            "POLICY_ID_*",
            "no POLICY_ID_* constants found in verify/mod.rs".into(),
        ));
        return drifts;
    }
    for (name, value) in &consts {
        let key = name.to_lowercase();
        match m.policies.get(&key) {
            None => drifts.push(Drift::new(
                "policy-ids",
                &key,
                format!(
                    "rust defines POLICY_ID_{name} but the manifest has \
                     no policy '{key}'"
                ),
            )),
            Some(&want) if want != *value => drifts.push(Drift::new(
                "policy-ids",
                &key,
                format!("rust id {value} != manifest id {want}"),
            )),
            Some(_) => {}
        }
    }
    for key in m.policies.keys() {
        if !consts.iter().any(|(n, _)| n.to_lowercase() == *key) {
            drifts.push(Drift::new(
                "policy-ids",
                key,
                format!(
                    "manifest policy '{key}' has no POLICY_ID_\
                     {} constant in verify/mod.rs",
                    key.to_uppercase()
                ),
            ));
        }
    }
    drifts
}

/// Layout consts: the required set is exported, and every const the
/// rust sources read by name exists — including the engine's `pack_max`
/// round-packing clamp, which must both exist and be referenced.
pub fn check_consts(
    m: &ContractManifest,
    sources: &[(&str, &str)],
) -> Vec<Drift> {
    let mut drifts = Vec::new();
    for name in REQUIRED_CONSTS {
        if !m.consts.contains_key(*name) {
            drifts.push(Drift::new(
                "layout-consts",
                name,
                "required const missing from the manifest".into(),
            ));
        }
    }
    let mut engine_refs_pack_max = false;
    for (label, src) in sources {
        let refs = extract::called_with_str(
            extract::strip_tests(src),
            &["konst", "konst_opt", "consts.get"],
        );
        for name in refs {
            if *label == "engine" && name == "pack_max" {
                engine_refs_pack_max = true;
            }
            if !m.consts.contains_key(&name) {
                drifts.push(Drift::new(
                    "layout-consts",
                    &name,
                    format!(
                        "{label} reads const '{name}' — not in the \
                         manifest"
                    ),
                ));
            }
        }
    }
    if !engine_refs_pack_max
        && sources.iter().any(|(label, _)| *label == "engine")
    {
        drifts.push(Drift::new(
            "layout-consts",
            "pack_max",
            "engine no longer clamps rounds_per_call to the layout's \
             pack_max const"
                .into(),
        ));
    }
    drifts
}

/// Exec-name registry, both directions: every name the rust sources
/// dispatch is in the manifest (soundness — a renamed python program
/// would orphan the rust caller), and every manifest executable is
/// referenced somewhere in the rust sources (completeness — a new
/// program nobody dispatches is dead weight or a missed hook-up).
pub fn check_exec_names(
    m: &ContractManifest,
    spec_src: &str,
    other_srcs: &[(&str, &str)],
) -> Vec<Drift> {
    let mut drifts = Vec::new();
    let mut referenced: Vec<(String, String)> = Vec::new(); // (name, site)
    let spec_nontest = extract::strip_tests(spec_src);
    for fn_name in [
        "exec_name",
        "multi_exec_name",
        "batch_exec_name",
        "batch_multi_exec_name",
    ] {
        match extract::fn_body(spec_nontest, fn_name) {
            None => drifts.push(Drift::new(
                "exec-names",
                fn_name,
                "fn not found in spec/mod.rs".into(),
            )),
            Some(body) => {
                for lit in extract::quoted(body) {
                    referenced.push((lit, format!("spec::{fn_name}")));
                }
            }
        }
    }
    for (label, src) in other_srcs {
        for lit in extract::called_with_str(
            extract::strip_tests(src),
            &["run", "has_exec"],
        ) {
            referenced.push((lit, (*label).to_string()));
        }
    }
    for (name, site) in &referenced {
        if !m.executables.contains_key(name) {
            drifts.push(Drift::new(
                "exec-names",
                name,
                format!(
                    "{site} dispatches '{name}' — not in the manifest's \
                     executable registry"
                ),
            ));
        }
    }
    // completeness: every registered executable must appear as a quoted
    // literal somewhere in the scanned non-test sources
    let mut all_literals: std::collections::BTreeSet<String> =
        referenced.into_iter().map(|(n, _)| n).collect();
    all_literals.extend(extract::quoted(spec_nontest));
    for (_, src) in other_srcs {
        all_literals.extend(extract::quoted(extract::strip_tests(src)));
    }
    for name in m.executables.keys() {
        if !all_literals.contains(name) {
            drifts.push(Drift::new(
                "exec-names",
                name,
                format!(
                    "manifest registers '{name}' but no scanned rust \
                     source references it"
                ),
            ));
        }
    }
    drifts
}

/// Wire protocol: every field name `request.rs` reads or writes must be
/// documented (quoted) in the `server.rs` module doc.
pub fn check_wire_fields(
    request_src: &str,
    server_src: &str,
) -> Vec<Drift> {
    let mut drifts = Vec::new();
    let doc = extract::module_doc(server_src);
    if doc.is_empty() {
        drifts.push(Drift::new(
            "wire-fields",
            "server.rs",
            "no module doc (//!) found to check against".into(),
        ));
        return drifts;
    }
    let mut fields: Vec<String> = extract::called_with_str(
        extract::strip_tests(request_src),
        &[".set", ".get", "fget"],
    );
    fields.sort();
    fields.dedup();
    for field in fields {
        if !doc.contains(&format!("\"{field}\"")) {
            drifts.push(Drift::new(
                "wire-fields",
                &field,
                format!(
                    "request.rs carries wire field \"{field}\" but the \
                     server.rs protocol doc never mentions it"
                ),
            ));
        }
    }
    drifts
}

/// BENCHMARKS.md must contain the canonical threshold table verbatim
/// (`mars bench diff --print-thresholds` regenerates it).
pub fn check_thresholds(benchmarks_md: &str) -> Vec<Drift> {
    let canonical = crate::bench::diff::thresholds_markdown();
    if benchmarks_md.contains(&canonical) {
        Vec::new()
    } else {
        vec![Drift::new(
            "bench-thresholds",
            "BENCHMARKS.md",
            "the regression-threshold table drifted from bench/diff.rs — \
             re-embed `mars bench diff --print-thresholds` output"
                .into(),
        )]
    }
}

/// Run every surface. `benchmarks_md` is `None` when the file could not
/// be located (reported as a drift — the gate must not silently skip).
pub fn run_all(
    m: &ContractManifest,
    s: &Sources,
    benchmarks_md: Option<&str>,
) -> CheckReport {
    let mut report = CheckReport::default();
    let mut push = |surface: &'static str, drifts: Vec<Drift>| {
        report.surfaces.push(surface);
        report.drifts.extend(drifts);
    };
    push("state-scalars", check_state_scalars(m, &s.state));
    push("cfg-slots", check_cfg(m, &s.runtime));
    push("policy-ids", check_policies(m, &s.verify));
    push(
        "layout-consts",
        check_consts(
            m,
            &[
                ("runtime", s.runtime.as_str()),
                ("engine", s.engine.as_str()),
                ("state", s.state.as_str()),
                ("replica", s.replica.as_str()),
            ],
        ),
    );
    push(
        "exec-names",
        check_exec_names(
            m,
            &s.spec,
            &[
                ("runtime", s.runtime.as_str()),
                ("engine", s.engine.as_str()),
                ("replica", s.replica.as_str()),
            ],
        ),
    );
    push("wire-fields", check_wire_fields(&s.request, &s.server));
    push(
        "bench-thresholds",
        match benchmarks_md {
            Some(text) => check_thresholds(text),
            None => vec![Drift::new(
                "bench-thresholds",
                "BENCHMARKS.md",
                "file not found — cannot verify the threshold table"
                    .into(),
            )],
        },
    );
    report
}

/// Resolved checker inputs (for the CLI's provenance line).
pub struct CheckPaths {
    /// The manifest actually loaded.
    pub manifest: PathBuf,
    /// The `rust/src` root the sources were read from.
    pub src_root: PathBuf,
    /// BENCHMARKS.md, when found.
    pub benchmarks: Option<PathBuf>,
}

/// Locate checker inputs relative to `repo_root`: an explicit
/// `--manifest` wins, then a freshly exported `<artifacts>/
/// contracts.json`, then the committed fixture
/// `rust/tests/fixtures/contracts.json` (so the gate runs on a bare
/// checkout). The source root tries `rust/src` then `src` (running
/// from the repo root vs from `rust/`).
pub fn resolve_paths(
    repo_root: &Path,
    manifest_flag: Option<&str>,
    src_flag: Option<&str>,
    artifact_dir: &Path,
) -> Result<CheckPaths> {
    let manifest = match manifest_flag {
        Some(p) => PathBuf::from(p),
        None => {
            let exported = artifact_dir.join("contracts.json");
            let fixtures = [
                repo_root.join("rust/tests/fixtures/contracts.json"),
                repo_root.join("tests/fixtures/contracts.json"),
            ];
            if exported.is_file() {
                exported
            } else {
                fixtures
                    .iter()
                    .find(|p| p.is_file())
                    .cloned()
                    .with_context(|| {
                        format!(
                            "no contracts.json: tried {} and the \
                             committed fixtures (export one with \
                             `python -m compile.contracts`)",
                            exported.display()
                        )
                    })?
            }
        }
    };
    let src_root = match src_flag {
        Some(p) => PathBuf::from(p),
        None => [repo_root.join("rust/src"), repo_root.join("src")]
            .into_iter()
            .find(|p| p.is_dir())
            .context("no rust source root (try --src DIR)")?,
    };
    let benchmarks = [
        repo_root.join("BENCHMARKS.md"),
        repo_root.join("../BENCHMARKS.md"),
    ]
    .into_iter()
    .find(|p| p.is_file());
    Ok(CheckPaths { manifest, src_root, benchmarks })
}

/// CLI entry: resolve paths, load everything, run, render. Returns the
/// report (the caller decides the exit code) plus the rendering.
pub fn run_cli(paths: &CheckPaths) -> Result<(CheckReport, String)> {
    let m = ContractManifest::load(&paths.manifest)?;
    let s = Sources::load(&paths.src_root)?;
    let bench_text = match &paths.benchmarks {
        Some(p) => Some(std::fs::read_to_string(p).with_context(|| {
            format!("reading {}", p.display())
        })?),
        None => None,
    };
    let report = run_all(&m, &s, bench_text.as_deref());
    let mut rendered = format!(
        "manifest: {} (hash {})\nsources:  {}\n",
        paths.manifest.display(),
        m.hash,
        paths.src_root.display(),
    );
    rendered.push_str(&report.render());
    Ok((report, rendered))
}

#[cfg(test)]
mod tests;
