//! The per-replica prefix-cache store: chain-hash keyed state snapshots,
//! token-equality confirmed, LRU-evicted under a byte budget.
//!
//! Entries are full flat-state snapshots (DESIGN.md §1.1 — single-buffer
//! state makes snapshot/restore a buffer copy plus the `pos` scalar), so
//! resident bytes are dominated by `state_len * 4` per entry and the
//! budget is the knob that matters (`--cache-mb`). Lookup probes every
//! prefix length of the prompt through the incremental chain hash and
//! returns the *longest* token-confirmed hit; a hash collision can cost a
//! probe, never a wrong restore.

use std::collections::HashMap;
use std::sync::Arc;

use super::key::PrefixHasher;

/// One cached snapshot: the exact token prefix it encodes plus the flat
/// device state pulled after that prefix was prefilled/committed. The
/// state is an `Arc<[f32]>` so a lookup hit hands back a shared handle
/// (refcount bump) instead of memcpy-ing the multi-MB vector on the hot
/// chat path; the resident copy is immutable by construction — the
/// restore path restamps its *own* working copy before upload.
struct CacheEntry {
    tokens: Vec<u32>,
    state: Arc<[f32]>,
    /// LRU clock value of the last insert/hit touching this entry.
    last_used: u64,
}

impl CacheEntry {
    fn bytes(&self) -> usize {
        self.state.len() * 4 + self.tokens.len() * 4
    }
}

/// Monotonic counters the store keeps about itself; published per replica
/// into the serving metrics (`coordinator::metrics` `"cache"` object) and
/// printed by `mars bench serve --scenario chat`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups that returned a reusable prefix.
    pub hits: u64,
    /// Lookups that found nothing usable.
    pub misses: u64,
    /// Snapshots stored (refreshing an identical prefix counts).
    pub insertions: u64,
    /// Entries dropped by LRU eviction or budget rejection.
    pub evictions: u64,
    /// Prompt tokens served from cache instead of prefilled.
    pub tokens_saved: u64,
    /// Bytes currently resident (gauge, not monotonic).
    pub bytes_resident: u64,
    /// Entries currently resident (gauge, not monotonic).
    pub entries: u64,
}

impl CacheStats {
    /// Hit fraction over all lookups (0 when none happened yet).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Prefix-reuse state cache for one engine replica (single-threaded by
/// construction, like the `Runtime` it snapshots — PJRT handles are not
/// `Send`, so neither are the replicas' caches shared).
pub struct PrefixCache {
    /// chain hash → entries whose token prefix folds to that hash
    /// (a bucket, because a 64-bit hash is an index, not an identity)
    buckets: HashMap<u64, Vec<CacheEntry>>,
    budget_bytes: usize,
    bytes_resident: usize,
    /// LRU clock: bumped on every insert and confirmed hit.
    tick: u64,
    hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
    tokens_saved: u64,
}

impl PrefixCache {
    /// Empty cache with `budget_bytes` of snapshot capacity.
    pub fn new(budget_bytes: usize) -> PrefixCache {
        PrefixCache {
            buckets: HashMap::new(),
            budget_bytes,
            bytes_resident: 0,
            tick: 0,
            hits: 0,
            misses: 0,
            insertions: 0,
            evictions: 0,
            tokens_saved: 0,
        }
    }

    /// Bytes currently resident (always <= the budget).
    pub fn bytes_resident(&self) -> usize {
        self.bytes_resident
    }

    /// Entries currently resident.
    pub fn entries(&self) -> usize {
        self.buckets.values().map(|b| b.len()).sum()
    }

    /// Counter/gauge snapshot for the metrics registry.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            insertions: self.insertions,
            evictions: self.evictions,
            tokens_saved: self.tokens_saved,
            bytes_resident: self.bytes_resident as u64,
            entries: self.entries() as u64,
        }
    }

    /// Store (or refresh) the snapshot of a token prefix. A snapshot too
    /// large for the whole budget is rejected rather than evicting
    /// everything for an entry that could never stay.
    pub fn insert(&mut self, tokens: &[u32], state: Vec<f32>) {
        if tokens.is_empty() {
            return;
        }
        self.insert_at(super::key::prefix_hash(tokens), tokens, state);
    }

    /// [`PrefixCache::insert`] with the bucket hash supplied by the
    /// caller — the seam the collision tests force mismatched buckets
    /// through; production code always derives it from `tokens`.
    fn insert_at(&mut self, hash: u64, tokens: &[u32], state: Vec<f32>) {
        self.tick += 1;
        let entry = CacheEntry {
            tokens: tokens.to_vec(),
            state: state.into(),
            last_used: self.tick,
        };
        let bytes = entry.bytes();
        if bytes > self.budget_bytes {
            self.evictions += 1;
            return;
        }
        let bucket = self.buckets.entry(hash).or_default();
        if let Some(old) = bucket.iter_mut().find(|e| e.tokens == tokens) {
            self.bytes_resident -= old.bytes();
            *old = entry;
        } else {
            bucket.push(entry);
        }
        self.bytes_resident += bytes;
        self.insertions += 1;
        self.evict_to_budget();
    }

    /// Longest token-confirmed cached prefix of `prompt`, or `None`.
    /// Returns the matched length and a shared handle to the snapshot —
    /// a refcount bump, not a copy: the caller restamps its own working
    /// copy before upload, so the resident snapshot stays immutable.
    /// `full_only` restricts the search to an exact whole-prompt hit —
    /// what the engine asks for when the artifact set lacks the
    /// `prefill_ext` suffix program.
    pub fn lookup(
        &mut self,
        prompt: &[u32],
        full_only: bool,
    ) -> Option<(usize, Arc<[f32]>)> {
        let mut hasher = PrefixHasher::new();
        let mut best: Option<(usize, u64)> = None;
        for (i, &t) in prompt.iter().enumerate() {
            let h = hasher.push(t);
            let l = i + 1;
            if full_only && l != prompt.len() {
                continue;
            }
            let confirmed = self
                .buckets
                .get(&h)
                .is_some_and(|b| b.iter().any(|e| e.tokens == prompt[..l]));
            if confirmed {
                best = Some((l, h));
            }
        }
        let (l, h) = best?;
        self.tick += 1;
        let tick = self.tick;
        let entry = self
            .buckets
            .get_mut(&h)
            .and_then(|b| b.iter_mut().find(|e| e.tokens == prompt[..l]))
            .expect("confirmed entry vanished");
        entry.last_used = tick;
        self.hits += 1;
        self.tokens_saved += l as u64;
        Some((l, entry.state.clone()))
    }

    /// Record a lookup that was never attempted as a miss (keeps hit-rate
    /// honest when the caller bails before probing, e.g. empty prompts).
    pub fn note_miss(&mut self) {
        self.misses += 1;
    }

    /// Rescind the accounting of a hit whose restore then failed (the
    /// engine fell back to a cold prefill): the hit becomes a miss and
    /// its `tokens_saved` are taken back, so the published hit rate and
    /// savings only ever describe reuse that actually happened.
    pub fn rescind_hit(&mut self, tokens_saved: usize) {
        self.hits = self.hits.saturating_sub(1);
        self.misses += 1;
        self.tokens_saved =
            self.tokens_saved.saturating_sub(tokens_saved as u64);
    }

    /// Evict least-recently-used entries until resident bytes fit the
    /// budget again.
    fn evict_to_budget(&mut self) {
        while self.bytes_resident > self.budget_bytes {
            let Some((&hash, idx)) = self
                .buckets
                .iter()
                .flat_map(|(h, b)| {
                    b.iter().enumerate().map(move |(i, e)| ((h, i), e))
                })
                .min_by_key(|(_, e)| e.last_used)
                .map(|((h, i), _)| (h, i))
            else {
                return;
            };
            let bucket = self.buckets.get_mut(&hash).expect("bucket");
            let victim = bucket.remove(idx);
            self.bytes_resident -= victim.bytes();
            self.evictions += 1;
            if bucket.is_empty() {
                self.buckets.remove(&hash);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(len: usize, fill: f32) -> Vec<f32> {
        vec![fill; len]
    }

    #[test]
    fn lookup_returns_longest_confirmed_prefix() {
        let mut c = PrefixCache::new(1 << 20);
        c.insert(&[1, 2], state(8, 0.2));
        c.insert(&[1, 2, 3, 4], state(8, 0.4));
        c.insert(&[9, 9], state(8, 0.9));
        let (l, s) = c.lookup(&[1, 2, 3, 4, 5, 6], false).expect("hit");
        assert_eq!(l, 4);
        assert_eq!(&s[..], &state(8, 0.4)[..]);
        let (l, s) = c.lookup(&[1, 2, 7], false).expect("short hit");
        assert_eq!(l, 2);
        assert_eq!(&s[..], &state(8, 0.2)[..]);
        assert!(c.lookup(&[2, 1], false).is_none());
        assert_eq!(c.stats().hits, 2);
        assert_eq!(c.stats().tokens_saved, 6);
    }

    #[test]
    fn full_only_rejects_partial_hits() {
        let mut c = PrefixCache::new(1 << 20);
        c.insert(&[1, 2], state(4, 0.5));
        assert!(c.lookup(&[1, 2, 3], true).is_none());
        assert_eq!(c.lookup(&[1, 2], true).map(|(l, _)| l), Some(2));
    }

    #[test]
    fn hash_collision_prefix_is_not_reused() {
        let mut c = PrefixCache::new(1 << 20);
        // force tokens [7, 8] into the bucket that [1, 2, 3]'s prefix
        // hash resolves to — exactly the wrong-restore a collision would
        // cause if lookup trusted the hash alone
        let collide = super::super::key::prefix_hash(&[1, 2, 3]);
        c.insert_at(collide, &[7, 8], state(4, 0.7));
        assert!(
            c.lookup(&[1, 2, 3], false).is_none(),
            "colliding bucket must fail token-equality confirmation"
        );
        // the honest owner of those tokens still hits
        assert!(c.lookup(&[7, 8], false).is_some());
    }

    #[test]
    fn lru_never_exceeds_budget_and_evicts_oldest() {
        // each entry: 64*4 state + 1*4 token = 260 bytes; budget fits 2
        let mut c = PrefixCache::new(600);
        c.insert(&[1], state(64, 0.1));
        c.insert(&[2], state(64, 0.2));
        assert!(c.bytes_resident() <= 600);
        assert_eq!(c.entries(), 2);
        // touch [1] so [2] becomes the LRU victim
        assert!(c.lookup(&[1], false).is_some());
        c.insert(&[3], state(64, 0.3));
        assert!(c.bytes_resident() <= 600);
        assert_eq!(c.entries(), 2);
        assert!(c.lookup(&[2], false).is_none(), "LRU entry survived");
        assert!(c.lookup(&[1], false).is_some());
        assert!(c.lookup(&[3], false).is_some());
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn oversized_snapshot_is_rejected_not_destructive() {
        let mut c = PrefixCache::new(100);
        c.insert(&[1], state(8, 0.1)); // 36 bytes, fits
        c.insert(&[2, 3], state(1024, 0.9)); // alone exceeds the budget
        assert!(c.bytes_resident() <= 100);
        assert!(c.lookup(&[1], false).is_some(), "resident entry evicted");
        assert!(c.lookup(&[2, 3], false).is_none());
    }

    #[test]
    fn refresh_replaces_in_place() {
        let mut c = PrefixCache::new(1 << 20);
        c.insert(&[5, 6], state(8, 0.1));
        c.insert(&[5, 6], state(8, 0.7));
        assert_eq!(c.entries(), 1);
        let (_, s) = c.lookup(&[5, 6], false).expect("hit");
        assert_eq!(&s[..], &state(8, 0.7)[..]);
    }

    #[test]
    fn lookup_hits_share_one_allocation() {
        // the zero-copy contract: two hits on one entry return handles
        // to the same resident snapshot, not fresh copies
        let mut c = PrefixCache::new(1 << 20);
        c.insert(&[4, 2], state(32, 0.4));
        let (_, a) = c.lookup(&[4, 2], false).expect("hit");
        let (_, b) = c.lookup(&[4, 2, 9], false).expect("hit");
        assert!(std::sync::Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn rescinded_hit_counts_as_a_miss() {
        let mut c = PrefixCache::new(1 << 20);
        c.insert(&[1, 2, 3], state(8, 0.3));
        let (l, _) = c.lookup(&[1, 2, 3, 4], false).expect("hit");
        assert_eq!((c.stats().hits, c.stats().tokens_saved), (1, 3));
        c.rescind_hit(l);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.tokens_saved), (0, 1, 0));
        assert!(s.hit_rate() < 1e-9);
    }

    #[test]
    fn stats_gauges_track_residency() {
        let mut c = PrefixCache::new(1 << 20);
        assert_eq!(c.stats(), CacheStats::default());
        c.insert(&[1, 2], state(16, 0.0));
        let s = c.stats();
        assert_eq!(s.entries, 1);
        assert_eq!(s.bytes_resident, 16 * 4 + 2 * 4);
        c.note_miss();
        assert_eq!(c.stats().misses, 1);
        assert!(c.stats().hit_rate() < 1e-9);
    }
}
