//! Prefix-reuse state cache — the fourth peer subsystem beside [`verify`],
//! [`spec`] and the serving layers (DESIGN.md §8).
//!
//! The dominant production traffic shape — multi-turn chat over a shared
//! system prompt — re-sends token prefixes that an earlier request on the
//! same replica already prefilled. Because the whole decode state is one
//! flat f32 vector (DESIGN.md §1.1), a snapshot is a buffer pull and a
//! restore is a restamp + upload: the [`PrefixCache`] keeps those
//! snapshots keyed by an incremental token chain hash
//! ([`key::prefix_hash`]) with token-equality confirmation, LRU-evicted
//! under a byte budget, and a new request prefills only the suffix past
//! its longest cached prefix (`prefill_ext`; full-prompt hits skip
//! prefill entirely and work on any artifact set).
//!
//! One configuration surface, matching the house one-codec-per-surface
//! convention of §6/§7:
//!
//! | surface      | form                                                  |
//! |--------------|-------------------------------------------------------|
//! | CLI          | `--cache-mb 256` (0 disables) on `serve` / `bench serve` |
//! | request JSON | `"cache": false` opts one request out of reuse        |
//! | router       | `--route prefix` — [`key::affinity_hash`] pins a conversation to one replica |
//! | metrics      | `"cache"` object: hit rate, tokens saved, bytes resident |
//!
//! Caches are **per replica** and single-threaded, like the `Runtime`
//! they snapshot — PJRT handles are not `Send`, so replica-local reuse +
//! prefix-affinity routing is the whole consistency story: there is no
//! cross-replica invalidation to get wrong. Verification policies and
//! drafting methods are orthogonal to reuse (the restamp re-encodes the
//! request's own config slots), so the cache composes with every
//! [`crate::verify::VerifyPolicy`] × [`crate::spec::SpecMethod`]
//! combination; the correctness pin is cached-vs-cold token identity at
//! T=0 (tests/integration.rs, tests/property.rs).
//!
//! [`verify`]: crate::verify
//! [`spec`]: crate::spec

#![warn(missing_docs)]

pub mod key;
pub mod store;

use std::cell::RefCell;
use std::rc::Rc;

pub use store::{CacheStats, PrefixCache};

/// A replica-thread-local shared handle to its [`PrefixCache`]: every
/// active [`crate::engine::SeqRunner`] of the replica borrows the one
/// store at snapshot/restore points (`Rc`, not `Arc` — the cache never
/// crosses the replica thread, exactly like the runtime it snapshots).
pub type SharedPrefixCache = Rc<RefCell<PrefixCache>>;

/// Default snapshot budget when `--cache-mb` is not given.
pub const DEFAULT_CACHE_MB: usize = 256;

/// Prefix-cache configuration carried from the CLI to each replica.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheConfig {
    /// Master switch (individual requests can still opt out with the
    /// wire field `"cache": false`).
    pub enabled: bool,
    /// Resident-snapshot budget per replica, in bytes.
    pub budget_bytes: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig::with_mb(DEFAULT_CACHE_MB)
    }
}

impl CacheConfig {
    /// Enabled config with an `mb` megabyte budget; `0` disables (the
    /// `--cache-mb 0` spelling of off).
    pub fn with_mb(mb: usize) -> CacheConfig {
        CacheConfig {
            enabled: mb > 0,
            budget_bytes: mb.saturating_mul(1 << 20),
        }
    }

    /// The disabled config.
    pub fn disabled() -> CacheConfig {
        CacheConfig { enabled: false, budget_bytes: 0 }
    }

    /// Canonical label for bench rows and logs: `cache:256mb` / `cache:off`.
    pub fn label(&self) -> String {
        if self.enabled {
            format!("cache:{}mb", self.budget_bytes >> 20)
        } else {
            "cache:off".to_string()
        }
    }

    /// Build the per-replica store (`None` when disabled).
    pub fn build(&self) -> Option<SharedPrefixCache> {
        self.enabled.then(|| {
            Rc::new(RefCell::new(PrefixCache::new(self.budget_bytes)))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_mb_and_labels() {
        let on = CacheConfig::with_mb(64);
        assert!(on.enabled);
        assert_eq!(on.budget_bytes, 64 << 20);
        assert_eq!(on.label(), "cache:64mb");
        let off = CacheConfig::with_mb(0);
        assert!(!off.enabled);
        assert_eq!(off.label(), "cache:off");
        assert!(CacheConfig::disabled().build().is_none());
        assert!(on.build().is_some());
        assert_eq!(CacheConfig::default(), CacheConfig::with_mb(256));
    }
}
