//! Prefix keying: an incremental chain hash over token prefixes.
//!
//! The cache key of a prefix `t[..l]` is the FNV-1a fold of its tokens in
//! order, so every prefix length of a prompt hashes in one left-to-right
//! pass ([`PrefixHasher`]) — the store probes all `l` candidate lengths of
//! a lookup in O(|prompt|) total. A 64-bit hash is an index, not an
//! identity: the store confirms every candidate by token equality before
//! reuse (DESIGN.md §8), so a collision can cost a probe, never a wrong
//! state restore.

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold one byte into an FNV-1a running hash.
#[inline]
fn fold_byte(h: u64, b: u8) -> u64 {
    (h ^ b as u64).wrapping_mul(FNV_PRIME)
}

/// Extend a prefix hash by one token (little-endian byte fold), so
/// `chain_hash(chain_hash(h, a), b)` is the hash of the prefix `.. a b`.
#[inline]
pub fn chain_hash(prev: u64, token: u32) -> u64 {
    token
        .to_le_bytes()
        .iter()
        .fold(prev, |h, &b| fold_byte(h, b))
}

/// Hash a full token prefix from the empty-prefix basis.
pub fn prefix_hash(tokens: &[u32]) -> u64 {
    tokens.iter().fold(FNV_OFFSET, |h, &t| chain_hash(h, t))
}

/// Incremental left-to-right prefix hasher: after `push(t_i)`, `hash()`
/// equals `prefix_hash(&tokens[..=i])`.
#[derive(Debug, Clone, Copy)]
pub struct PrefixHasher {
    h: u64,
}

impl PrefixHasher {
    /// Start at the empty prefix.
    pub fn new() -> PrefixHasher {
        PrefixHasher { h: FNV_OFFSET }
    }

    /// Fold the next token of the prefix.
    pub fn push(&mut self, token: u32) -> u64 {
        self.h = chain_hash(self.h, token);
        self.h
    }

    /// Hash of the prefix folded so far.
    pub fn hash(&self) -> u64 {
        self.h
    }
}

impl Default for PrefixHasher {
    fn default() -> Self {
        PrefixHasher::new()
    }
}

/// Cap on the prompt-head bytes the `prefix_affinity` router policy
/// hashes (guards against pathological single-line prompts).
pub const AFFINITY_PREFIX_BYTES: usize = 48;

/// Hash the routing head of a prompt for `RouterPolicy::PrefixAffinity`:
/// the first line (through its `\n` — the system-prompt line every turn
/// of a conversation repeats verbatim), capped at
/// [`AFFINITY_PREFIX_BYTES`]. Every later turn of a conversation extends
/// its first turn byte-for-byte, so all of them hash to the same replica
/// — the one whose per-replica prefix cache holds that conversation's
/// snapshots (DESIGN.md §8) — and conversations sharing a system prompt
/// co-locate, concentrating the shared-prefix hits.
pub fn affinity_hash(prompt: &str) -> u64 {
    let bytes = prompt.as_bytes();
    let line = bytes
        .iter()
        .position(|&b| b == b'\n')
        .map(|i| i + 1)
        .unwrap_or(bytes.len());
    bytes[..line.min(AFFINITY_PREFIX_BYTES)]
        .iter()
        .fold(FNV_OFFSET, |h, &b| fold_byte(h, b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn incremental_matches_batch() {
        let toks = [3u32, 0, 917, 42, 42, 7];
        let mut hasher = PrefixHasher::new();
        for l in 0..toks.len() {
            assert_eq!(hasher.hash(), prefix_hash(&toks[..l]), "prefix {l}");
            hasher.push(toks[l]);
        }
        assert_eq!(hasher.hash(), prefix_hash(&toks));
    }

    #[test]
    fn order_and_length_sensitive() {
        assert_ne!(prefix_hash(&[1, 2]), prefix_hash(&[2, 1]));
        assert_ne!(prefix_hash(&[1, 2]), prefix_hash(&[1, 2, 0]));
        assert_ne!(prefix_hash(&[]), prefix_hash(&[0]));
    }

    #[test]
    fn affinity_follows_the_system_line() {
        // all turns of one conversation repeat the system line verbatim,
        // whatever their total length — they must hash identically
        let turn1 = "Sys: be brief.\nU: capital of Zorland?\nB:";
        let turn2 = "Sys: be brief.\nU: capital of Zorland?\nB: Mirefal\n\
                     U: and of Quovia?\nB:";
        assert_eq!(affinity_hash(turn1), affinity_hash(turn2));
        // a different system prompt routes elsewhere
        assert_ne!(
            affinity_hash(turn1),
            affinity_hash("Sys: verbose.\nU: capital of Zorland?\nB:")
        );
        // single-line prompts hash their capped head and stay stable
        let long = "x".repeat(AFFINITY_PREFIX_BYTES + 20);
        let longer = format!("{long}yyy");
        assert_eq!(affinity_hash(&long), affinity_hash(&longer));
    }
}
