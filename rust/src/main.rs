//! `mars` — CLI entrypoint for the MARS serving stack.
//!
//! ```text
//! mars info                          artifact + model summary
//! mars generate --prompt "..."       one-shot generation
//! mars serve --bind 127.0.0.1:7071   line-JSON TCP serving
//! mars bench <table1..table7|fig3|policies|packing|batch|perf|serve|all>
//! mars bench diff old.json new.json  schema-2 snapshot regression gate
//! mars analyze <fig1|fig4>           probe-ring dumps + ASCII plots
//! mars trace summarize FILE          aggregate a --trace JSONL span log
//! mars eval --task arith --method eagle_tree [--policy mars:0.9]
//! mars check contracts               cross-layer contract checker
//! ```

#![forbid(unsafe_code)]

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use mars::bench::{self, BenchCtx};
use mars::coordinator::router::{Router, RouterPolicy};
use mars::coordinator::server;
use mars::datasets::{dataset, Task};
use mars::engine::{DecodeEngine, GenParams, SpecMethod};
use mars::runtime::{Artifacts, Runtime};
use mars::util::cli::Args;
use mars::verify::VerifyPolicy;

// the one sanctioned `process::exit` site (clippy.toml disallows it
// elsewhere: bypassing drop handlers mid-stack loses buffered replies)
#[allow(clippy::disallowed_methods)]
fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(
        &argv,
        &[
            "mars",
            "no-mars",
            "hostloop",
            "probe",
            "quiet",
            "help",
            "no-cache",
            "print-thresholds",
            "reset",
        ],
    ) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.has("help") || args.subcommand.is_none() {
        usage();
        return;
    }
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "mars — Margin-Aware Speculative Verification serving stack

USAGE: mars <cmd> [flags]

  info                       artifact + model summary
  generate --prompt TEXT     one-shot generation
      [--method ar|sps:k=7|eagle_chain:k=7|eagle_tree:k=7,beam=2,branch=2
               |medusa:k=4|pld:min=2,max=4,k=7|lookahead:n=3,g=8,cap=4096,k=7]
      [--k 7] [--beam 2] [--branch 2]    (legacy aliases for --method knobs)
      [--policy strict|mars:0.9|topk:2:0.1|entropy:1.5]
      [--mars|--no-mars] [--theta 0.9]   (legacy aliases for --policy)
      [--temperature 1.0] [--max-new 128] [--seed 0] [--hostloop]
      [--pack 1]   rounds fused per device call (round packing)
  serve [--bind ADDR] [--replicas 1] [--slots 4] [--route rr|ll|prefix]
      [--cache-mb 256]   per-replica prefix-cache budget (0 disables)
      [--pack 1]   server default rounds_per_call (requests override
          with \"rounds_per_call\"; streaming slots always run unpacked)
      [--batch 1]  cross-sequence batch width: decode up to N requests
          per device dispatch (needs batching-capable artifacts;
          requests join/leave at round boundaries)
      [--trace FILE]     per-request JSONL span log (queue -> prefill ->
          rounds -> commit); summarize with `mars trace summarize FILE`
      [--prom-addr ADDR] Prometheus text exposition on
          http://ADDR/metrics (same payload as {{\"cmd\": \"prom\"}})
      [--deadline-ms N]  default per-request wall budget (requests
          override with \"deadline_ms\"; partial text is returned with
          \"deadline_exceeded\": true when it runs out)
      [--shed-above N]   refuse new requests with {{\"busy\": true,
          \"retry_after_ms\": ...}} once the queued backlog reaches N
      [--fault-plan SPEC] deterministic fault injection, e.g.
          dispatch=0.2,latency=0.05:250,rebuild=0.5,seed=7,only=0
          (DESIGN.md §13; chaos testing — not for production)
      line-JSON protocol: pipelined ids, \"stream\": true deltas,
      \"cache\": false opt-out, {{\"cmd\": \"cancel\", \"id\": N}},
      {{\"cmd\": \"metrics\", \"reset\": true}}, {{\"cmd\": \"prom\"}} —
      see coordinator/server.rs docs
  bench table1|..|table7|fig3|perf|policies|packing|batch|serve|all
      [--n 16] [--seed 7] [--max-new 96]
      [--methods sps:k=6,eagle_tree,pld]      (policies/packing/batch/
          serve; defaults: every speculative method in the registry /
          sps + eagle_tree / the default tree)
      [--policies strict,mars:0.9,topk:2,entropy:1.5]   (policies/
          packing/batch/serve; packing + batch default to strict,mars:0.9)
      [--packs 1,2,4,8,16]   rounds_per_call sweep list     (packing)
      [--batches 1,2,4,8]    occupancy sweep list            (batch)
      [--connections 4] [--rate 8.0] [--replicas 1] [--slots 4]
          [--batch 1]   cross-sequence batch width per replica   (serve)
      [--scenario sweep|chat] [--turns 3] [--cache-mb 256]        (serve;
          chat = multi-turn conversations, cache-on vs cache-off waves)
      [--fault-plan SPEC] [--deadline-ms N] [--shed-above N]      (serve;
          chaos benchmarking — same grammar as `mars serve`)
      [--reset]   zero server metrics between serve waves via
          {{\"cmd\": \"metrics\", \"reset\": true}}              (serve)
      [--out DIR]   redirect emit paths: BENCH_*.json trajectories
          into DIR, rendered tables into DIR/results
  bench diff OLD.json NEW.json [--out FILE]
      pair two schema-2 snapshots by record key, apply per-metric
      direction thresholds (see BENCHMARKS.md), exit nonzero on
      regression; `estimated` baselines soft-gate (WARN, exit 0)
  analyze fig1|fig4 [--n 24] [--policy mars:0.9]
  trace summarize FILE
      aggregate a serve --trace JSONL span log: per-phase span counts,
      wall-time quantiles, acceptance mix across traced rounds
  eval --task arith|code|chat|sum|mt [--method M] [--policy P] [--n 16]
  check contracts [--manifest FILE] [--src DIR]
      diff the python-exported contract manifest (contracts.json; export
      with `python -m compile.contracts`) against the rust mirrors:
      state scalars, cfg slots, policy ids, layout consts, exec names,
      wire fields, bench thresholds; exits nonzero naming every drift

  global: --artifacts DIR (default ./artifacts or $MARS_ARTIFACTS)"
    );
}

fn artifact_dir(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(Artifacts::default_dir)
}

/// Parse `--fault-plan SPEC` (fault-injection grammar, DESIGN.md §13);
/// `None` when the flag is absent.
fn fault_from_args(args: &Args) -> Result<Option<mars::fault::FaultSpec>> {
    match args.get("fault-plan") {
        None => Ok(None),
        Some(s) => mars::fault::FaultSpec::parse(s)
            .map(Some)
            .map_err(|e| anyhow!("bad --fault-plan '{s}': {e}")),
    }
}

/// Resolve the verification policy: `--policy STR` wins; the legacy
/// `--mars` / `--no-mars` / `--theta θ` flags still map onto
/// `Mars { theta }` / `Strict`.
fn policy_from_args(args: &Args) -> Result<VerifyPolicy> {
    if let Some(s) = args.get("policy") {
        return VerifyPolicy::parse(s)
            .map(|p| p.normalize_for_device())
            .ok_or_else(|| anyhow!("bad policy '{s}' (try strict|mars:0.9|topk:2:0.1|entropy:1.5)"));
    }
    if args.has("no-mars") {
        return Ok(VerifyPolicy::Strict);
    }
    let theta = args.get_f64("theta", 0.9) as f32;
    if args.has("mars") || args.get("theta").is_some() {
        return Ok(VerifyPolicy::Mars { theta });
    }
    Ok(VerifyPolicy::default())
}

/// Resolve the method descriptor: `--method STR` (full descriptor
/// grammar) wins the family; the legacy `--k` / `--beam` / `--branch`
/// flags then override the descriptor's matching knobs.
fn method_from_args(args: &Args) -> Result<SpecMethod> {
    let mut m = match args.get("method") {
        None => SpecMethod::default(),
        Some(s) => SpecMethod::parse(s).ok_or_else(|| {
            anyhow!(
                "bad method '{s}' (try ar|sps:k=7|eagle_tree:k=7,beam=2,\
                 branch=2|medusa|pld:min=2,max=4|lookahead:n=3,g=8)"
            )
        })?,
    };
    let ov = |key: &str| args.get(key).and_then(|s| s.parse::<usize>().ok());
    m = m.with_overrides(ov("k"), ov("beam"), ov("branch"));
    Ok(m)
}

fn gen_params(args: &Args) -> Result<GenParams> {
    let d = GenParams::default();
    Ok(GenParams {
        method: method_from_args(args)?,
        policy: policy_from_args(args)?,
        temperature: args.get_f64("temperature", d.temperature as f64) as f32,
        max_new: args.get_usize("max-new", d.max_new),
        seed: args.get_usize("seed", d.seed as usize) as u64,
        probe: args.has("probe"),
        extract_every: args.get_usize("extract-every", 1),
        rounds_per_call: args.get_usize("pack", d.rounds_per_call).max(1),
        cache: !args.has("no-cache"),
    })
}

fn run(args: &Args) -> Result<()> {
    let dir = artifact_dir(args);
    match args.subcommand.as_deref().unwrap() {
        "info" => {
            let a = Artifacts::load(&dir)?;
            println!("artifacts: {}", dir.display());
            println!("state_len: {}", a.layout.state_len);
            println!("layout hash: {}", a.layout.hash);
            println!("executables:");
            for name in a.executable_names() {
                println!("  {name}");
            }
            Ok(())
        }
        "generate" => {
            let prompt = args
                .get("prompt")
                .ok_or_else(|| anyhow!("--prompt required"))?
                .to_string();
            let params = gen_params(args)?;
            let rt = Runtime::new(&dir)?;
            let mut engine = DecodeEngine::new(rt);
            engine.hostloop = args.has("hostloop");
            let r = engine.generate(&prompt, &params)?;
            println!("{}", r.text);
            eprintln!(
                "--\n{} tokens in {:.3}s decode ({:.1} tok/s), tau={:.2}, \
                 relaxed={}, rounds={}, device_calls={}",
                r.tokens.len(),
                r.decode_seconds,
                r.tok_per_sec(),
                r.tau(),
                r.snapshot.relaxed_accepts,
                r.snapshot.rounds,
                r.device_calls,
            );
            Ok(())
        }
        "serve" => {
            let bind = args.get_or("bind", "127.0.0.1:7071");
            let replicas = args.get_usize("replicas", 1);
            let slots = args.get_usize("slots", 4);
            // routing policy is --route; --policy everywhere else means
            // the verification policy, so it is not aliased here
            let route = args.get_or("route", "ll");
            let policy = RouterPolicy::parse(&route)
                .ok_or_else(|| anyhow!("bad routing policy '{route}'"))?;
            let cache = mars::cache::CacheConfig::with_mb(
                args.get_usize("cache-mb", mars::cache::DEFAULT_CACHE_MB),
            );
            let trace = match args.get("trace") {
                None => None,
                Some(p) => Some(Arc::new(
                    mars::obs::trace::TraceWriter::create(Path::new(p))?,
                )),
            };
            let mut rcfg = mars::coordinator::router::RouterConfig::new(&dir);
            rcfg.replicas = replicas;
            rcfg.slots = slots;
            rcfg.hostloop = args.has("hostloop");
            rcfg.policy = policy;
            rcfg.cache = cache;
            rcfg.pack = args.get_usize("pack", 1).max(1);
            rcfg.batch = args.get_usize("batch", 1).max(1);
            rcfg.trace = trace;
            rcfg.fault = fault_from_args(args)?;
            rcfg.deadline_ms =
                args.get("deadline-ms").and_then(|s| s.parse::<u64>().ok());
            rcfg.shed_above =
                args.get("shed-above").and_then(|s| s.parse::<usize>().ok());
            let router = Arc::new(Router::start(rcfg)?);
            let handle = server::serve(router.clone(), &bind)?;
            println!("serving on {} ({} replicas)", handle.addr, replicas);
            // the prom endpoint thread holds its own Arc<Router>; it dies
            // with the process after the drain below
            if let Some(addr) = args.get("prom-addr") {
                let r = router.clone();
                let srv = mars::obs::prom::serve_http(addr, move || {
                    r.metrics.render_prometheus()
                })?;
                println!("prometheus exposition on http://{}/metrics", srv.addr);
            }
            println!(
                "protocol: one JSON object per line; pipelined \"id\"s, \
                 \"stream\": true for deltas, {{\"cmd\":\"cancel\",\"id\":N}}, \
                 {{\"cmd\":\"shutdown\"}} to stop (drains in-flight work)"
            );
            // block until the shutdown command flips the flag
            while !handle.stopped() {
                std::thread::sleep(std::time::Duration::from_millis(200));
            }
            // graceful drain: let in-flight sequences finish (bounded) so
            // every connection flushes its terminal replies before exit
            let t0 = std::time::Instant::now();
            while router.active_total() > 0
                && t0.elapsed() < std::time::Duration::from_secs(60)
            {
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
            // one beat for connection writer threads to flush the socket
            std::thread::sleep(std::time::Duration::from_millis(100));
            println!(
                "metrics: {}",
                router.metrics.snapshot_json().to_string_json()
            );
            Ok(())
        }
        "bench" => {
            let which = args
                .positional
                .first()
                .map(|s| s.as_str())
                .unwrap_or("all");
            let sweep = || -> Result<Vec<VerifyPolicy>> {
                let spec = args
                    .get("policies")
                    .unwrap_or("strict,mars:0.9,topk:2,entropy:1.5");
                VerifyPolicy::parse_list(spec)
                    .map(|v| {
                        v.into_iter()
                            .map(|p| p.normalize_for_device())
                            .collect()
                    })
                    .ok_or_else(|| anyhow!("bad --policies list '{spec}'"))
            };
            // `--methods` sweep list (descriptor grammar); the default
            // differs per target: `policies` sweeps every speculative
            // family in the registry, `serve` drives the default tree
            let msweep = |default: Vec<SpecMethod>| -> Result<Vec<SpecMethod>> {
                match args.get("methods") {
                    None => Ok(default),
                    Some(spec) => SpecMethod::parse_list(spec)
                        .ok_or_else(|| anyhow!("bad --methods list '{spec}'")),
                }
            };
            // `bench diff` compares two committed snapshot files — no
            // artifacts, no engine: handle it before Runtime::new
            if which == "diff" {
                // canonical threshold table — what BENCHMARKS.md embeds
                // verbatim (`mars check contracts` verifies)
                if args.has("print-thresholds") {
                    print!("{}", bench::diff::thresholds_markdown());
                    return Ok(());
                }
                let usage = "usage: mars bench diff OLD.json NEW.json";
                let old = args
                    .positional
                    .get(1)
                    .ok_or_else(|| anyhow!("{usage}"))?;
                let new = args
                    .positional
                    .get(2)
                    .ok_or_else(|| anyhow!("{usage}"))?;
                let (report, rendered) = bench::diff::run_diff(
                    &PathBuf::from(old),
                    &PathBuf::from(new),
                    &bench::diff::DiffCfg::default(),
                )?;
                println!("{rendered}");
                if let Some(out) = args.get("out") {
                    std::fs::write(out, &rendered)?;
                    eprintln!("[written {out}]");
                }
                if report.regressed() {
                    let fails = report.failures();
                    bail!(
                        "{} regression(s) past threshold, first: {}",
                        fails.len(),
                        fails[0].key
                    );
                }
                return Ok(());
            }
            // `--out DIR`: redirect both emit paths (BENCH_*.json
            // trajectories into DIR, rendered tables into DIR/results)
            let out_dir = args.get("out").map(PathBuf::from);
            // the serving benchmark owns its own router/replicas (each
            // replica builds a Runtime), so handle it before the bare
            // single-engine context below
            if which == "serve" {
                let scenario = match args.get_or("scenario", "sweep").as_str()
                {
                    "sweep" | "mix" => bench::serve::ServeScenario::Sweep,
                    "chat" => bench::serve::ServeScenario::Chat {
                        turns: args.get_usize("turns", 3),
                    },
                    other => bail!("unknown serve scenario '{other}'"),
                };
                let cfg = bench::serve::ServeBenchCfg {
                    artifact_dir: dir.clone(),
                    replicas: args.get_usize("replicas", 1),
                    slots: args.get_usize("slots", 4),
                    batch: args.get_usize("batch", 1).max(1),
                    connections: args.get_usize("connections", 4),
                    n_requests: args.get_usize("n", 24),
                    rate_per_s: args.get_f64("rate", 8.0),
                    max_new: args.get_usize("max-new", 48),
                    seed: args.get_usize("seed", 7) as u64,
                    methods: msweep(vec![SpecMethod::default()])?,
                    policies: sweep()?,
                    scenario,
                    reset: args.has("reset"),
                    fault: fault_from_args(args)?,
                    deadline_ms: args
                        .get("deadline-ms")
                        .and_then(|s| s.parse::<u64>().ok()),
                    shed_above: args
                        .get("shed-above")
                        .and_then(|s| s.parse::<usize>().ok()),
                    cache_mb: args
                        .get_usize("cache-mb", mars::cache::DEFAULT_CACHE_MB),
                    out_dir: out_dir
                        .as_ref()
                        .map(|d| d.join("results"))
                        .unwrap_or_else(|| PathBuf::from("results")),
                    bench_dir: out_dir
                        .clone()
                        .unwrap_or_else(|| PathBuf::from(".")),
                };
                return bench::serve::run(&cfg);
            }
            let rt = Runtime::new(&dir)?;
            let engine = DecodeEngine::new(rt);
            let mut ctx =
                BenchCtx::new(&engine, args.get_usize("n", 16), args.get_usize("seed", 7) as u64);
            ctx.max_new = args.get_usize("max-new", 96);
            if let Some(d) = &out_dir {
                ctx.out_dir = d.join("results");
                ctx.bench_dir = d.clone();
            }
            match which {
                "table1" => bench::table1(&ctx)?,
                "table2" => bench::table2(&ctx)?,
                "table3" => bench::table3(&ctx)?,
                "table4" => bench::table4(&ctx)?,
                "table5" => bench::table5(&ctx)?,
                "table6" => bench::table6(&ctx)?,
                "table7" => bench::table7(&ctx)?,
                "fig3" => bench::fig3(&ctx)?,
                "perf" => bench::perf(&ctx, &dir)?,
                "policies" => bench::policy_sweep(
                    &ctx,
                    &msweep(SpecMethod::speculative_defaults())?,
                    &sweep()?,
                )?,
                "packing" => {
                    // the dispatch-tax sweep wants a tight default grid:
                    // the two acceptance families x the two headline
                    // policies (override with --methods / --policies)
                    let spec = args.get_or("packs", "1,2,4,8,16");
                    let packs: Vec<usize> = spec
                        .split(',')
                        .map(|s| {
                            s.trim().parse::<usize>().ok().filter(|&p| p >= 1)
                        })
                        .collect::<Option<Vec<usize>>>()
                        .ok_or_else(|| anyhow!("bad --packs list '{spec}'"))?;
                    let policies = match args.get("policies") {
                        None => vec![
                            VerifyPolicy::Strict,
                            VerifyPolicy::Mars { theta: 0.9 },
                        ],
                        Some(_) => sweep()?,
                    };
                    bench::packing(
                        &ctx,
                        &msweep(vec![
                            SpecMethod::Sps { k: 7 },
                            SpecMethod::default(),
                        ])?,
                        &policies,
                        &packs,
                    )?
                }
                "batch" => {
                    // the occupancy sweep mirrors `packing`'s grid: the
                    // two acceptance families x the two headline
                    // policies (override with --methods / --policies)
                    let spec = args.get_or("batches", "1,2,4,8");
                    let batches: Vec<usize> = spec
                        .split(',')
                        .map(|s| {
                            s.trim().parse::<usize>().ok().filter(|&b| b >= 1)
                        })
                        .collect::<Option<Vec<usize>>>()
                        .ok_or_else(|| anyhow!("bad --batches list '{spec}'"))?;
                    let policies = match args.get("policies") {
                        None => vec![
                            VerifyPolicy::Strict,
                            VerifyPolicy::Mars { theta: 0.9 },
                        ],
                        Some(_) => sweep()?,
                    };
                    bench::batch(
                        &ctx,
                        &msweep(vec![
                            SpecMethod::Sps { k: 7 },
                            SpecMethod::default(),
                        ])?,
                        &policies,
                        &batches,
                    )?
                }
                "all" => {
                    bench::table1(&ctx)?;
                    bench::table2(&ctx)?;
                    bench::table3(&ctx)?;
                    bench::table4(&ctx)?;
                    bench::table5(&ctx)?;
                    bench::table6(&ctx)?;
                    bench::table7(&ctx)?;
                    bench::fig3(&ctx)?;
                    bench::policy_sweep(
                        &ctx,
                        &msweep(SpecMethod::speculative_defaults())?,
                        &sweep()?,
                    )?;
                    bench::perf(&ctx, &dir)?;
                }
                other => bail!("unknown bench '{other}'"),
            }
            Ok(())
        }
        "analyze" => {
            let which = args
                .positional
                .first()
                .map(|s| s.as_str())
                .unwrap_or("fig1");
            analyze(args, &dir, which)
        }
        "trace" => {
            let usage = "usage: mars trace summarize FILE";
            let verb = args
                .positional
                .first()
                .ok_or_else(|| anyhow!("{usage}"))?;
            if verb != "summarize" {
                bail!("unknown trace verb '{verb}' (try summarize)");
            }
            let file = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow!("{usage}"))?;
            let s = mars::obs::trace::summarize(Path::new(file))?;
            print!("{}", mars::obs::trace::render_summary(&s));
            Ok(())
        }
        "check" => {
            let which = args
                .positional
                .first()
                .map(|s| s.as_str())
                .unwrap_or("contracts");
            if which != "contracts" {
                bail!("unknown check '{which}' (try contracts)");
            }
            let paths = mars::check::resolve_paths(
                Path::new("."),
                args.get("manifest"),
                args.get("src"),
                &dir,
            )?;
            let (report, rendered) = mars::check::run_cli(&paths)?;
            print!("{rendered}");
            if !report.ok() {
                bail!(
                    "{} contract drift(s) — rust mirrors disagree with \
                     the python-exported manifest",
                    report.drifts.len()
                );
            }
            Ok(())
        }
        "eval" => {
            let task = Task::parse(&args.get_or("task", "arith"))
                .ok_or_else(|| anyhow!("bad task"))?;
            let params = gen_params(args)?;
            let rt = Runtime::new(&dir)?;
            let engine = DecodeEngine::new(rt);
            let ctx = BenchCtx::new(
                &engine,
                args.get_usize("n", 16),
                args.get_usize("seed", 7) as u64,
            );
            let e = ctx.run_task(task, &params)?;
            println!(
                "task={} method={} policy={} -> acc={:.3} rouge={:.3} \
                 bleu={:.2} chrf={:.2} judge={:.2} tau={:.2} tok/s={:.1}",
                task.name(),
                params.method.label(),
                params.policy.label(),
                e.quality.accuracy,
                e.quality.rouge_l,
                e.quality.bleu,
                e.quality.chrf,
                e.quality.judge,
                e.tau,
                e.mean_tok_per_s
            );
            Ok(())
        }
        other => {
            bail!("unknown subcommand '{other}' (try --help)")
        }
    }
}

/// Figures 1 & 4: run probe-enabled generations and dump (z1, z2) stats.
fn analyze(args: &Args, dir: &PathBuf, which: &str) -> Result<()> {
    let rt = Runtime::new(dir)?;
    let engine = DecodeEngine::new(rt);
    let n = args.get_usize("n", 24);
    let mut params = gen_params(args)?;
    params.probe = true;
    params.method = SpecMethod::default();
    if !params.policy.is_relaxed() {
        // the probe figures need relaxed acceptances to plot
        params.policy = VerifyPolicy::default();
    }

    let mut entries = Vec::new();
    for (i, task) in Task::all().iter().enumerate() {
        for (j, ex) in dataset(*task, n / 5 + 1, 11).iter().enumerate() {
            let mut p = params.clone();
            p.seed = (i * 100 + j) as u64;
            let r = engine.generate(&ex.prompt, &p)?;
            if let Some(probe) = r.probe {
                entries.extend(probe.entries);
            }
        }
    }
    std::fs::create_dir_all("results")?;
    let csv_path = format!("results/{which}_probe.csv");
    let mut csv = String::from("z1,z2,logit_ratio,prob_ratio,flag\n");
    for e in &entries {
        let r = if e.z1 > 0.0 && e.z2 > 0.0 { e.z2 / e.z1 } else { 0.0 };
        let pr = (e.z2 - e.z1).exp();
        csv.push_str(&format!(
            "{:.4},{:.4},{:.4},{:.5},{}\n",
            e.z1, e.z2, r, pr, e.flag as u8
        ));
    }
    std::fs::write(&csv_path, &csv)?;
    println!("wrote {} probe entries to {csv_path}", entries.len());

    match which {
        "fig1" => {
            // scatter summary: relaxed points by logit-ratio band
            let mut out = String::from(
                "## Figure 1 — logit ratio vs probability ratio\n\n\
                 | band (r) | total | accepted-exact | relaxed | rejected | \
                 mean p2/p1 |\n|---|---|---|---|---|---|\n",
            );
            for band in 0..10 {
                let lo = band as f32 / 10.0;
                let hi = lo + 0.1;
                let in_band: Vec<_> = entries
                    .iter()
                    .filter(|e| {
                        let r = if e.z1 > 0.0 && e.z2 > 0.0 {
                            e.z2 / e.z1
                        } else {
                            -1.0
                        };
                        r >= lo && r < hi
                    })
                    .collect();
                if in_band.is_empty() {
                    continue;
                }
                let cnt = |f: mars::verify::AcceptFlag| {
                    in_band.iter().filter(|e| e.flag == f).count()
                };
                let mean_pr = in_band
                    .iter()
                    .map(|e| ((e.z2 - e.z1).exp()) as f64)
                    .sum::<f64>()
                    / in_band.len() as f64;
                out.push_str(&format!(
                    "| {lo:.1}-{hi:.1} | {} | {} | {} | {} | {mean_pr:.3} |\n",
                    in_band.len(),
                    cnt(mars::verify::AcceptFlag::Exact),
                    cnt(mars::verify::AcceptFlag::Relaxed),
                    cnt(mars::verify::AcceptFlag::Reject)
                ));
            }
            out.push_str(
                "\nRelaxed (MARS) acceptances concentrate in the top band \
                 r>0.9, and span the full p2/p1 range — the metric \
                 decoupling of Fig. 1c.\n",
            );
            println!("{out}");
            std::fs::write("results/fig1.md", out)?;
        }
        "fig4" => {
            let hist = |vals: Vec<f32>, lo: f32, hi: f32, bins: usize| {
                let mut h = vec![0usize; bins];
                for v in &vals {
                    let t = ((v - lo) / (hi - lo) * bins as f32) as isize;
                    let t = t.clamp(0, bins as isize - 1) as usize;
                    h[t] += 1;
                }
                h
            };
            let z1s: Vec<f32> = entries.iter().map(|e| e.z1).collect();
            let neg = z1s.iter().filter(|&&z| z < 0.0).count();
            let ratios: Vec<f32> = entries
                .iter()
                .filter(|e| e.z1 > 0.0 && e.z2 > 0.0)
                .map(|e| e.z2 / e.z1)
                .collect();
            let prs: Vec<f32> =
                entries.iter().map(|e| (e.z2 - e.z1).exp()).collect();
            let mut out = String::from("## Figure 4 — top-2 statistics\n\n");
            out.push_str(&format!(
                "(a) top-1 logit: n={}, negative fraction = {:.2}% \
                 (paper: 0.0%)\n\n",
                z1s.len(),
                100.0 * neg as f64 / z1s.len().max(1) as f64
            ));
            out.push_str("(b) logit ratio z2/z1 histogram (0..1):\n```\n");
            out.push_str(&ascii_hist(&hist(ratios, 0.0, 1.0, 20), 0.0, 1.0));
            out.push_str("```\n(c) prob ratio p2/p1 histogram (0..1):\n```\n");
            out.push_str(&ascii_hist(&hist(prs, 0.0, 1.0, 20), 0.0, 1.0));
            out.push_str("```\n");
            println!("{out}");
            std::fs::write("results/fig4.md", out)?;
        }
        other => bail!("unknown analyze '{other}'"),
    }
    Ok(())
}

fn ascii_hist(h: &[usize], lo: f32, hi: f32, ) -> String {
    let max = *h.iter().max().unwrap_or(&1);
    let mut s = String::new();
    for (i, &c) in h.iter().enumerate() {
        let frac_lo = lo + (hi - lo) * i as f32 / h.len() as f32;
        let bar = "#".repeat((c * 50 / max.max(1)).max(usize::from(c > 0)));
        s.push_str(&format!("{frac_lo:5.2} | {bar} {c}\n"));
    }
    s
}
