//! Synthetic evaluation datasets — the rust mirror of
//! `python/compile/data.py` (same templates; seeds need not bit-match the
//! python corpus, only the distribution).
//!
//! Task families map to the paper's benchmarks by *metric family*
//! (DESIGN.md §1.3):
//!
//! | task  | paper benchmark | metric                    |
//! |-------|-----------------|---------------------------|
//! | arith | GSM8K           | exact-match final answer  |
//! | code  | HumanEval/MBPP  | avg@k output match        |
//! | chat  | MT-Bench/Alpaca | judge score               |
//! | sum   | CNN/DailyMail   | ROUGE-L vs lead-1         |
//! | mt    | WMT19 Zh-En     | BLEU / chrF               |

use crate::util::prng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Task {
    Arith,
    Code,
    Chat,
    Sum,
    Mt,
}

impl Task {
    pub fn all() -> &'static [Task] {
        &[Task::Arith, Task::Code, Task::Chat, Task::Sum, Task::Mt]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Task::Arith => "arith",
            Task::Code => "code",
            Task::Chat => "chat",
            Task::Sum => "sum",
            Task::Mt => "mt",
        }
    }

    pub fn parse(s: &str) -> Option<Task> {
        Some(match s {
            "arith" | "gsm8k" => Task::Arith,
            "code" | "humaneval" => Task::Code,
            "chat" | "mtbench" | "alpaca" => Task::Chat,
            "sum" | "cnndm" => Task::Sum,
            "mt" | "wmt" | "wmt19" => Task::Mt,
            _ => return None,
        })
    }

    /// Paper benchmark this task substitutes for (table headers).
    pub fn paper_name(&self) -> &'static str {
        match self {
            Task::Arith => "GSM8K*",
            Task::Code => "HumanEval*",
            Task::Chat => "Alpaca*",
            Task::Sum => "CNN/DM*",
            Task::Mt => "WMT19*",
        }
    }
}

/// One evaluation example.
#[derive(Debug, Clone)]
pub struct Example {
    pub task: Task,
    pub prompt: String,
    /// gold completion (reference text for quality metrics)
    pub reference: String,
    /// gold final answer for exact-match tasks (arith/code)
    pub answer: Option<String>,
    /// keywords the chat judge checks
    pub keywords: Vec<String>,
}

pub fn generate(task: Task, rng: &mut Rng) -> Example {
    match task {
        Task::Arith => gen_arith(rng),
        Task::Code => gen_code(rng),
        Task::Chat => gen_chat(rng),
        Task::Sum => gen_sum(rng),
        Task::Mt => gen_mt(rng),
    }
}

pub fn dataset(task: Task, n: usize, seed: u64) -> Vec<Example> {
    let mut rng = Rng::new(seed ^ 0xD00D ^ (task as u64) << 8);
    (0..n).map(|_| generate(task, &mut rng)).collect()
}

// ------------------------------------------------------------- arith -------

fn gen_arith(rng: &mut Rng) -> Example {
    let kind = rng.usize_below(3);
    let (prompt, completion) = match kind {
        0 => {
            let (mut a, mut b) =
                (rng.range(2, 99), rng.range(2, 99));
            let op = *rng.pick(&['+', '-', '*']);
            if op == '-' && b > a {
                std::mem::swap(&mut a, &mut b);
            }
            let (a, b) = if op == '*' {
                (rng.range(2, 12), rng.range(2, 12))
            } else {
                (a, b)
            };
            let val = match op {
                '+' => a + b,
                '-' => a - b,
                _ => a * b,
            };
            (format!("Q: {a}{op}{b}=?\nA: "), format!("{val}\n"))
        }
        1 => {
            let (a, b, c) =
                (rng.range(2, 9), rng.range(2, 9), rng.range(2, 9));
            let inner = b + c;
            let val = a * inner;
            (
                format!("Q: {a}*({b}+{c})=?\nA: "),
                format!("{b}+{c}={inner}; {a}*{inner}={val}\n"),
            )
        }
        _ => {
            let xs: Vec<i64> =
                (0..3).map(|_| rng.range(1, 50)).collect();
            let s1 = xs[0] + xs[1];
            let s2 = s1 + xs[2];
            (
                format!("Q: {}+{}+{}=?\nA: ", xs[0], xs[1], xs[2]),
                format!("{}+{}={s1}; {s1}+{}={s2}\n", xs[0], xs[1], xs[2]),
            )
        }
    };
    let answer = arith_answer(&completion);
    Example {
        task: Task::Arith,
        prompt,
        reference: completion,
        answer: Some(answer),
        keywords: vec![],
    }
}

/// Final answer = last integer in the completion (mirror of data.py).
pub fn arith_answer(completion: &str) -> String {
    let cleaned = completion.trim().replace(';', " ");
    for tok in cleaned.split_whitespace().rev() {
        let t = tok.rsplit('=').next().unwrap_or(tok);
        let t2 = t.trim_start_matches('-');
        if !t2.is_empty() && t2.chars().all(|c| c.is_ascii_digit()) {
            return t.to_string();
        }
    }
    String::new()
}

// -------------------------------------------------------------- code -------

const WORDS: &[&str] = &[
    "ab", "cat", "dog", "sun", "map", "key", "box", "red", "ice", "owl",
    "pin", "fox", "jam", "log", "net", "orb", "paw", "rug", "sky", "toe",
];

fn zip2(a: &str, b: &str) -> String {
    a.chars()
        .zip(b.chars())
        .flat_map(|(x, y)| [x, y])
        .collect()
}

fn gen_code(rng: &mut Rng) -> Example {
    let fns = ["rep", "rev", "up", "cat", "zip2"];
    let f = *rng.pick(&fns);
    let w = rng.pick(WORDS).to_string();
    let (call, out) = match f {
        "rep" => {
            let n = rng.range(2, 5) as usize;
            (format!("rep('{w}',{n})"), w.repeat(n))
        }
        "rev" => (format!("rev('{w}')"), w.chars().rev().collect()),
        "up" => (format!("up('{w}')"), w.to_uppercase()),
        "cat" => {
            let w2 = rng.pick(WORDS).to_string();
            (format!("cat('{w}','{w2}')"), format!("{w}{w2}"))
        }
        _ => {
            let w2 = rng.pick(WORDS).to_string();
            let m = w.len().min(w2.len());
            let (a, b) = (&w[..m], &w2[..m]);
            (format!("zip2('{a}','{b}')"), zip2(a, b))
        }
    };
    Example {
        task: Task::Code,
        prompt: format!(">>> {call}\n"),
        reference: format!("'{out}'\n"),
        answer: Some(format!("'{out}'")),
        keywords: vec![],
    }
}

// -------------------------------------------------------------- chat -------

const KB: &[(&str, &str)] = &[
    ("Zorland", "Mirefal"), ("Quovia", "Bruntal"), ("Aldora", "Seaphor"),
    ("Vintria", "Caldus"), ("Norvand", "Tessily"), ("Ostrevia", "Palmyre"),
    ("Kelluna", "Dorvane"), ("Merrowin", "Ashford"), ("Tallgard", "Rivermoor"),
    ("Ulmstead", "Graypost"), ("Firelund", "Coldbay"), ("Westmarch", "Highfen"),
];
const COLORS: &[(&str, &str)] = &[
    ("bryleaf", "green"), ("sunpetal", "yellow"), ("mooncap", "white"),
    ("ashroot", "gray"), ("embervine", "red"), ("frostfern", "blue"),
];
const OPINIONS: &[(&str, &str)] = &[
    ("the sea", "The sea is wide and calm at dawn."),
    ("the forest", "The forest is quiet and full of tall trees."),
    ("the city", "The city is busy and bright at night."),
    ("the desert", "The desert is dry and still under the sun."),
    ("the mountain", "The mountain is steep and cold at the top."),
];

fn gen_chat(rng: &mut Rng) -> Example {
    match rng.usize_below(3) {
        0 => {
            let (c, cap) = *rng.pick(KB);
            Example {
                task: Task::Chat,
                prompt: format!("User: What is the capital of {c}?\nBot: "),
                reference: format!("The capital of {c} is {cap}.\n"),
                answer: None,
                keywords: vec![c.to_string(), cap.to_string()],
            }
        }
        1 => {
            let (plant, col) = *rng.pick(COLORS);
            Example {
                task: Task::Chat,
                prompt: format!("User: What color is the {plant} plant?\nBot: "),
                reference: format!("The {plant} plant is {col}.\n"),
                answer: None,
                keywords: vec![plant.to_string(), col.to_string()],
            }
        }
        _ => {
            let (topic, sent) = *rng.pick(OPINIONS);
            // judge keywords: content words of the gold sentence
            let keywords: Vec<String> = sent
                .split_whitespace()
                .map(|w| w.trim_matches('.').to_string())
                .filter(|w| w.len() >= 4 && w.chars().next().unwrap().is_lowercase())
                .take(3)
                .collect();
            Example {
                task: Task::Chat,
                prompt: format!(
                    "User: Write one sentence about {topic}.\nBot: "
                ),
                reference: format!("{sent}\n"),
                answer: None,
                keywords,
            }
        }
    }
}

// --------------------------------------------------------------- sum -------

const SUBJ: &[&str] = &["The mayor", "A farmer", "The team", "One pilot",
    "The crew", "A doctor", "The judge", "A singer", "The coach", "An actor"];
const VERB: &[&str] = &["opened", "visited", "repaired", "sold", "found",
    "built", "closed", "painted", "moved", "won"];
const OBJ: &[&str] = &["the old bridge", "a small market", "the north road",
    "a red barn", "the city hall", "a fishing boat", "the corn field",
    "a stone well", "the town clock", "a long fence"];
const WHEN: &[&str] = &["on Monday", "last week", "in the spring", "at noon",
    "after the storm", "before dawn", "in early May", "this year"];

fn sentence(rng: &mut Rng) -> String {
    format!(
        "{} {} {} {}.",
        rng.pick(SUBJ),
        rng.pick(VERB),
        rng.pick(OBJ),
        rng.pick(WHEN)
    )
}

fn gen_sum(rng: &mut Rng) -> Example {
    // 2 sentences keeps prompts inside the P_MAX=160 budget
    let sents: Vec<String> = (0..2).map(|_| sentence(rng)).collect();
    Example {
        task: Task::Sum,
        prompt: format!("Text: {}\nSummary: ", sents.join(" ")),
        reference: format!("{}\n", sents[0]),
        answer: None,
        keywords: vec![],
    }
}

// ---------------------------------------------------------------- mt -------

const CIPHER_SHIFT: u8 = 7;

/// Deterministic substitution cipher (the "source language").
pub fn cipher_encode(text: &str) -> String {
    text.chars()
        .map(|c| {
            if c.is_ascii_lowercase() {
                (((c as u8 - b'a' + CIPHER_SHIFT) % 26) + b'a') as char
            } else {
                c
            }
        })
        .collect()
}

const MT_POOL: &[&str] = &[
    "the river runs past the mill",
    "a cold wind moves the tall grass",
    "the old man sells bread at the market",
    "two boats wait near the stone pier",
    "rain fell on the quiet village at night",
    "the children walk to school along the canal",
    "a gray cat sleeps on the warm roof",
    "the train leaves the station before sunrise",
    "farmers bring apples and corn to the square",
    "lanterns light the narrow street in winter",
    "the baker opens his shop at dawn",
    "soldiers marched over the wooden bridge",
    "a letter arrived from the far coast",
    "the bell rings twice at the old tower",
    "ships carry salt and wool across the bay",
    "the girl paints small birds on paper",
];

fn gen_mt(rng: &mut Rng) -> Example {
    let mut src = rng.pick(MT_POOL).to_string();
    if rng.bool(0.5) {
        let other = rng.pick(MT_POOL);
        let a: Vec<&str> = src.split_whitespace().take(4).collect();
        let b: Vec<&str> = other.split_whitespace().skip(4).collect();
        if !b.is_empty() {
            src = a
                .into_iter()
                .chain(b)
                .collect::<Vec<_>>()
                .join(" ");
        }
    }
    Example {
        task: Task::Mt,
        prompt: format!("Translate: {}\nOutput: ", cipher_encode(&src)),
        reference: format!("{src}\n"),
        answer: None,
        keywords: vec![],
    }
}

// ------------------------------------------------------- conversations ----

/// System prompts shared *across* conversations (a small pool on
/// purpose: conversations drawing the same system line share a cacheable
/// prefix and co-locate under `prefix_affinity` routing — DESIGN.md §8).
const SYSTEMS: &[&str] = &[
    "Sys: kb bot, be terse.\n",
    "Sys: one-line answers.\n",
    "Sys: short replies only.\n",
    "Sys: answer briefly.\n",
];

/// One synthetic multi-turn chat conversation: a shared system prompt
/// plus short user turns over the chat knowledge base. Turn prompts are
/// built so that each one *extends the previous turn's prompt + answer
/// byte-for-byte* — exactly the traffic shape the prefix cache and the
/// `chat` serve scenario exploit.
#[derive(Debug, Clone)]
pub struct Conversation {
    /// System line every turn of this conversation starts with.
    pub system: String,
    /// User turns, each already formatted as `U: ...?\nB:`.
    pub turns: Vec<String>,
}

impl Conversation {
    /// Serving prompt of turn `t` (0-based) given the answers to the
    /// previous turns: `system ++ turn_0 ++ answer_0 ++ "\n" ++ ... ++
    /// turn_t`. With `answers` as the verbatim reply texts, the turn-`t`
    /// prompt is a strict byte prefix of the turn-`t+1` prompt.
    pub fn prompt(&self, t: usize, answers: &[String]) -> String {
        let mut p = self.system.clone();
        for i in 0..t {
            p.push_str(&self.turns[i]);
            if let Some(a) = answers.get(i) {
                p.push_str(a);
            }
            p.push('\n');
        }
        p.push_str(&self.turns[t]);
        p
    }
}

/// Generate `n` multi-turn conversations of `turns` short user turns
/// each (seed-deterministic). Prompts are kept terse so a 3-turn
/// conversation with short answers stays inside the `P_MAX` prompt
/// budget of the default artifact build.
pub fn chat_conversations(n: usize, turns: usize, seed: u64) -> Vec<Conversation> {
    let mut rng = Rng::new(seed ^ 0xC0A7);
    (0..n)
        .map(|_| {
            let system = rng.pick(SYSTEMS).to_string();
            let turns = (0..turns.max(1))
                .map(|_| match rng.usize_below(3) {
                    0 => {
                        let (c, _) = *rng.pick(KB);
                        format!("U: capital of {c}?\nB:")
                    }
                    1 => {
                        let (plant, _) = *rng.pick(COLORS);
                        format!("U: color of {plant}?\nB:")
                    }
                    _ => {
                        let (topic, _) = *rng.pick(OPINIONS);
                        format!("U: describe {topic}.\nB:")
                    }
                })
                .collect();
            Conversation { system, turns }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let a = dataset(Task::Arith, 5, 42);
        let b = dataset(Task::Arith, 5, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.reference, y.reference);
        }
    }

    #[test]
    fn arith_answers_consistent() {
        for ex in dataset(Task::Arith, 50, 1) {
            let ans = ex.answer.unwrap();
            assert!(!ans.is_empty());
            assert!(ex.reference.trim().ends_with(&ans), "{}", ex.reference);
        }
    }

    #[test]
    fn arith_answer_extracts_last_value() {
        assert_eq!(arith_answer("4+5=9; 3*9=27\n"), "27");
        assert_eq!(arith_answer("95\n"), "95");
        assert_eq!(arith_answer("no digits"), "");
    }

    #[test]
    fn cipher_is_reversible_shift() {
        let enc = cipher_encode("abc xyz");
        assert_eq!(enc, "hij efg");
        // applying shift 26-7=19 more times inverts
        let dec: String = enc
            .chars()
            .map(|c| {
                if c.is_ascii_lowercase() {
                    (((c as u8 - b'a' + 19) % 26) + b'a') as char
                } else {
                    c
                }
            })
            .collect();
        assert_eq!(dec, "abc xyz");
    }

    #[test]
    fn code_outputs_match_semantics() {
        for ex in dataset(Task::Code, 50, 2) {
            let ans = ex.answer.unwrap();
            assert!(ex.reference.trim() == ans);
            assert!(ex.prompt.starts_with(">>> "));
        }
    }

    #[test]
    fn sum_reference_is_lead_sentence() {
        for ex in dataset(Task::Sum, 20, 3) {
            let body = ex.prompt.strip_prefix("Text: ").unwrap();
            assert!(body.starts_with(ex.reference.trim()));
        }
    }

    #[test]
    fn prompts_fit_prompt_budget() {
        // P_MAX = 160 in the default artifact build
        for task in Task::all() {
            for ex in dataset(*task, 100, 4) {
                assert!(
                    ex.prompt.len() <= 160,
                    "{} prompt too long: {} chars",
                    task.name(),
                    ex.prompt.len()
                );
            }
        }
    }

    #[test]
    fn conversations_deterministic_and_turn_prompts_nest() {
        let a = chat_conversations(6, 3, 5);
        let b = chat_conversations(6, 3, 5);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.system, y.system);
            assert_eq!(x.turns, y.turns);
        }
        // the cacheable-prefix contract: turn t's prompt extended by its
        // answer is a byte prefix of turn t+1's prompt
        let answers =
            vec![" Mirefal".to_string(), " green".to_string()];
        for conv in &a {
            for t in 1..conv.turns.len() {
                let prev = conv.prompt(t - 1, &answers);
                let next = conv.prompt(t, &answers);
                let grown = format!("{prev}{}\n", answers[t - 1]);
                assert!(
                    next.starts_with(&grown),
                    "turn {t} does not extend turn {}: {next:?}",
                    t - 1
                );
            }
        }
    }

    #[test]
    fn conversations_fit_prompt_budget_with_short_answers() {
        // P_MAX = 160 in the default artifact build; the chat serve
        // scenario runs max_new <= 12 so answers stay ~12 bytes
        let answer = "x".repeat(12);
        for conv in chat_conversations(20, 3, 9) {
            let answers = vec![answer.clone(); 3];
            let last = conv.prompt(2, &answers);
            assert!(
                last.len() <= 160,
                "3-turn prompt too long ({}): {last:?}",
                last.len()
            );
        }
    }

    #[test]
    fn all_tasks_generate() {
        for task in Task::all() {
            let d = dataset(*task, 3, 9);
            assert_eq!(d.len(), 3);
            assert!(d.iter().all(|e| !e.prompt.is_empty()));
            assert!(d.iter().all(|e| e.reference.ends_with('\n')));
        }
    }
}
