//! Micro-benchmarks of the host-side verification-adjacent hot paths:
//! tokenizer, PLD n-gram lookup, lookahead pool, JSON codec, metrics.
//! These are the L3 pieces that run per round outside the device.

mod bench_util;

use bench_util::bench_fn;
use mars::spec::{HostDrafter, LookaheadDrafter, PldDrafter};
use mars::util::json::Value;
use mars::util::prng::Rng;

fn main() {
    println!("== verify/host-path micro benches ==");
    let mut rng = Rng::new(1);
    let history: Vec<u32> =
        (0..2048).map(|_| rng.below(96) as u32 + 4).collect();

    let mut pld = PldDrafter::default();
    bench_fn("pld_draft/2k_history", 300, || {
        let d = pld.draft(&history, 8);
        std::hint::black_box(d);
    });

    let mut la = LookaheadDrafter::default();
    la.observe(&history);
    bench_fn("lookahead_draft/2k_history", 300, || {
        let d = la.draft(&history, 8);
        std::hint::black_box(d);
    });
    bench_fn("lookahead_observe/incremental", 300, || {
        let mut la2 = LookaheadDrafter::default();
        la2.observe(&history[..512]);
        std::hint::black_box(la2.pool_len());
    });

    let text = "Q: 37+58=?\nA: 4+5=9; 3*9=27\n".repeat(8);
    bench_fn("tokenizer_encode/224B", 200, || {
        std::hint::black_box(mars::tokenizer::encode(&text));
    });
    let ids = mars::tokenizer::encode(&text);
    bench_fn("tokenizer_decode/224tok", 200, || {
        std::hint::black_box(mars::tokenizer::decode(&ids));
    });

    let payload = r#"{"prompt":"Q: 1+2=?\nA: ","method":"eagle_tree",
        "mars":true,"theta":0.9,"temperature":1.0,"k":7,"max_new":64}"#;
    bench_fn("json_parse/request", 200, || {
        std::hint::black_box(Value::parse(payload).unwrap());
    });
    let v = Value::parse(payload).unwrap();
    bench_fn("json_write/request", 200, || {
        std::hint::black_box(v.to_string_json());
    });
}
