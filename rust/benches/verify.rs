//! Micro-benchmarks of the host-side verification-adjacent hot paths:
//! the policy layer (parse / JSON / slot codec / reference scan),
//! tokenizer, PLD n-gram lookup, lookahead pool, JSON codec.
//! These are the L3 pieces that run per round outside the device.
//!
//! The policy set is swept from one flag:
//! `cargo bench --bench verify -- --policies strict,mars:0.9,topk:2,entropy:1.5`

mod bench_util;

use bench_util::bench_fn;
use mars::spec::{
    HostDrafter, LookaheadDrafter, PldDrafter, SpecMethod, METHODS,
};
use mars::util::json::Value;
use mars::util::prng::Rng;
use mars::verify::VerifyPolicy;

/// `--policies a,b,c` from argv (cargo bench passes everything after `--`).
fn sweep_from_args() -> Vec<VerifyPolicy> {
    let default = "strict,mars:0.9,topk:2,entropy:1.5";
    let args: Vec<String> = std::env::args().collect();
    let spec = args
        .iter()
        .position(|a| a == "--policies")
        .and_then(|i| args.get(i + 1).cloned())
        .or_else(|| {
            args.iter()
                .find_map(|a| a.strip_prefix("--policies=").map(String::from))
        })
        .unwrap_or_else(|| default.to_string());
    VerifyPolicy::parse_list(&spec).unwrap_or_else(|| {
        eprintln!("bad --policies '{spec}', using default");
        VerifyPolicy::parse_list(default).unwrap()
    })
}

fn main() {
    println!("== verify/host-path micro benches ==");
    let mut rng = Rng::new(1);

    // ---- policy layer, swept over the requested policies ---------------
    let policies = sweep_from_args();
    println!(
        "policy sweep: {}",
        policies
            .iter()
            .map(|p| p.label())
            .collect::<Vec<_>>()
            .join(",")
    );
    // synthetic verification rows: (tstar, top-4) + drafts
    let t = 64usize;
    let rows: Vec<(u32, Vec<(u32, f32)>)> = (0..t)
        .map(|_| {
            let z1 = rng.f64() as f32 * 8.0 + 0.5;
            let top: Vec<(u32, f32)> = (0..4)
                .map(|j| {
                    (
                        rng.below(128) as u32,
                        z1 * (1.0 - 0.05 * j as f32),
                    )
                })
                .collect();
            (top[0].0, top)
        })
        .collect();
    let drafts: Vec<u32> = rows
        .iter()
        .map(|(tstar, top)| if rng.bool(0.5) { *tstar } else { top[1].0 })
        .collect();

    for &p in &policies {
        let label = p.label();
        bench_fn(&format!("policy_scan/{label}/64pos"), 200, || {
            std::hint::black_box(p.scan(&drafts, &rows));
        });
        bench_fn(&format!("policy_parse/{label}"), 100, || {
            std::hint::black_box(VerifyPolicy::parse(&label));
        });
        bench_fn(&format!("policy_json_roundtrip/{label}"), 100, || {
            let v = p.to_json();
            let back = Value::parse(&v.to_string_json()).unwrap();
            std::hint::black_box(VerifyPolicy::from_json(&back).unwrap());
        });
        bench_fn(&format!("policy_slots_roundtrip/{label}"), 100, || {
            std::hint::black_box(
                VerifyPolicy::decode_slots(p.encode_slots()).unwrap(),
            );
        });
    }

    // ---- method-descriptor codecs (one per registry row) ----------------
    for info in METHODS {
        let label = info.default.label();
        bench_fn(&format!("method_parse/{}", info.name), 100, || {
            std::hint::black_box(SpecMethod::parse(&label));
        });
        bench_fn(&format!("method_json_roundtrip/{}", info.name), 100, || {
            let v = info.default.to_json();
            let back = Value::parse(&v.to_string_json()).unwrap();
            std::hint::black_box(SpecMethod::from_json(&back).unwrap());
        });
    }

    // ---- host drafters --------------------------------------------------
    let history: Vec<u32> =
        (0..2048).map(|_| rng.below(96) as u32 + 4).collect();

    let mut pld = PldDrafter::default();
    bench_fn("pld_draft/2k_history", 300, || {
        let d = pld.draft(&history, 8);
        std::hint::black_box(d);
    });

    let mut la = LookaheadDrafter::default();
    la.observe(&history);
    bench_fn("lookahead_draft/2k_history", 300, || {
        let d = la.draft(&history, 8);
        std::hint::black_box(d);
    });
    bench_fn("lookahead_observe/incremental", 300, || {
        let mut la2 = LookaheadDrafter::default();
        la2.observe(&history[..512]);
        std::hint::black_box(la2.pool_len());
    });

    // ---- tokenizer + wire codec ----------------------------------------
    let text = "Q: 37+58=?\nA: 4+5=9; 3*9=27\n".repeat(8);
    bench_fn("tokenizer_encode/224B", 200, || {
        std::hint::black_box(mars::tokenizer::encode(&text));
    });
    let ids = mars::tokenizer::encode(&text);
    bench_fn("tokenizer_decode/224tok", 200, || {
        std::hint::black_box(mars::tokenizer::decode(&ids));
    });

    let payload = r#"{"prompt":"Q: 1+2=?\nA: ","method":"eagle_tree",
        "policy":{"mars":{"theta":0.9}},"temperature":1.0,"k":7,"max_new":64}"#;
    bench_fn("json_parse/request", 200, || {
        std::hint::black_box(Value::parse(payload).unwrap());
    });
    let v = Value::parse(payload).unwrap();
    bench_fn("json_write/request", 200, || {
        std::hint::black_box(v.to_string_json());
    });
}
