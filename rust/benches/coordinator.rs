//! Coordinator benches: router submission overhead, metrics recording,
//! scheduler queue ops — the L3 control plane must never be the
//! bottleneck next to ~ms device rounds.

mod bench_util;

use std::sync::Arc;

use bench_util::bench_fn;
use mars::coordinator::metrics::{MetricsRegistry, RequestMetrics};
use mars::util::stats::{LogHistogram, Summary};

fn main() {
    println!("== coordinator micro benches ==");

    let reg = Arc::new(MetricsRegistry::new());
    let m = RequestMetrics {
        ok: true,
        tokens: 64,
        decode_seconds: 0.2,
        prefill_seconds: 0.01,
        queue_seconds: 0.001,
        ttft_seconds: 0.015,
        tau: 6.0,
        relaxed_accepts: 3.0,
        policy: "mars",
        method: "eagle_tree",
    };
    bench_fn("metrics_record", 200, || {
        reg.record(m);
    });
    bench_fn("metrics_snapshot_json", 200, || {
        std::hint::black_box(reg.snapshot_json().to_string_json());
    });

    bench_fn("summary_percentile/10k", 300, || {
        let mut s = Summary::new();
        for i in 0..10_000 {
            s.push(i as f64);
        }
        std::hint::black_box(s.p99());
    });

    bench_fn("log_histogram_record/10k", 300, || {
        let mut h = LogHistogram::default();
        for i in 0..10_000u64 {
            h.record(i as f64);
        }
        std::hint::black_box(h.quantile(0.99));
    });
}
