//! Shared micro-bench harness (criterion is not in the offline registry).
//! `harness = false` benches call [`bench_fn`] which warms up, runs timed
//! iterations, and prints mean / p50 / p99 like criterion's summary line.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

/// Time `f` adaptively: aim for ~`target_ms` of total measurement.
pub fn bench_fn<F: FnMut()>(name: &str, target_ms: u64, mut f: F) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().as_nanos().max(1) as u64;
    let iters = ((target_ms as u128 * 1_000_000) / one as u128)
        .clamp(5, 100_000) as usize;
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: p(0.5),
        p99_ns: p(0.99),
    };
    println!(
        "{:40} {:>8} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
        r.name,
        r.iters,
        fmt_ns(r.mean_ns),
        fmt_ns(r.p50_ns),
        fmt_ns(r.p99_ns)
    );
    r
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}us", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1_000_000.0)
    } else {
        format!("{:.2}s", ns / 1_000_000_000.0)
    }
}

/// Artifacts present? Benches that need the model self-skip otherwise.
pub fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::env::var("MARS_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"));
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!(
            "[skip] artifacts not found at {} — run `make artifacts`",
            dir.display()
        );
        None
    }
}
