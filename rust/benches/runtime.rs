//! Runtime-layer benches (need artifacts): per-round dispatch cost for
//! each executable, extract cost, resident-state vs hostloop — the §Perf
//! numbers in EXPERIMENTS.md come from here.

mod bench_util;

use bench_util::{artifacts_dir, bench_fn};
use mars::engine::{DecodeEngine, GenParams, Method};
use mars::runtime::Runtime;

fn main() {
    let Some(dir) = artifacts_dir() else { return };
    println!("== runtime benches ==");
    let rt = Runtime::new(&dir).expect("runtime");
    println!("(compile at startup: {:.2}s)", rt.compile_seconds);

    let prompt = mars::tokenizer::encode("Q: 12+34=?\nA: ");
    let base = GenParams {
        method: Method::EagleTree,
        policy: mars::verify::VerifyPolicy::Mars { theta: 0.9 },
        temperature: 1.0,
        max_new: 48,
        ..GenParams::default()
    };

    // per-round cost per method (resident state)
    for (name, method) in [
        ("ar_step", Method::Ar),
        ("sps_round", Method::Sps),
        ("eagle_tree_round", Method::EagleTree),
        ("medusa_round", Method::Medusa),
    ] {
        let mut p = base.clone();
        p.method = method;
        let mut sess = rt.session(&prompt, &p).expect("session");
        let exec = match method {
            Method::Ar => "ar_step",
            Method::Sps => "sps_round",
            Method::Medusa => "medusa_round",
            _ => "eagle_tree_round",
        };
        bench_fn(&format!("round/{name}"), 1500, || {
            sess.round(exec).expect("round");
        });
        let _ = name;
    }

    // extract cost
    {
        let mut sess = rt.session(&prompt, &base).expect("session");
        bench_fn("extract/snapshot", 800, || {
            let s = sess.extract().expect("extract");
            std::hint::black_box(s.out_len);
        });
    }

    // resident vs hostloop end-to-end
    let engine = DecodeEngine::new(Runtime::new(&dir).expect("rt"));
    bench_fn("e2e/resident_state/48tok", 4000, || {
        let r = engine.generate("Q: 12+34=?\nA: ", &base).expect("gen");
        std::hint::black_box(r.tokens.len());
    });
    let mut engine_h = DecodeEngine::new(Runtime::new(&dir).expect("rt"));
    engine_h.hostloop = true;
    bench_fn("e2e/hostloop/48tok", 4000, || {
        let r = engine_h.generate("Q: 12+34=?\nA: ", &base).expect("gen");
        std::hint::black_box(r.tokens.len());
    });
}
