//! Runtime-layer benches (need artifacts): per-round dispatch cost for
//! each executable, extract cost, resident-state vs hostloop — the §Perf
//! numbers in EXPERIMENTS.md come from here.

mod bench_util;

use bench_util::{artifacts_dir, bench_fn};
use mars::engine::{DecodeEngine, GenParams, SpecMethod};
use mars::runtime::Runtime;

fn main() {
    let Some(dir) = artifacts_dir() else { return };
    println!("== runtime benches ==");
    let rt = Runtime::new(&dir).expect("runtime");
    println!("(compile at startup: {:.2}s)", rt.compile_seconds);

    let prompt = mars::tokenizer::encode("Q: 12+34=?\nA: ");
    let base = GenParams {
        method: SpecMethod::default(),
        policy: mars::verify::VerifyPolicy::Mars { theta: 0.9 },
        temperature: 1.0,
        max_new: 48,
        ..GenParams::default()
    };

    // per-round cost of every device-drafted method in the registry.
    // Host drafters go through round_ext (covered by the verify bench's
    // drafter section); eagle_chain is skipped so `eagle_tree_round` is
    // timed at the full default tree config, not the degenerate beam-1
    // chain that shares its executable.
    for method in SpecMethod::all_defaults() {
        let exec = method.exec_name();
        if exec == "verify_ext_round" || method.name() == "eagle_chain" {
            continue;
        }
        let mut p = base.clone();
        p.method = method;
        let mut sess = rt.session(&prompt, &p).expect("session");
        bench_fn(&format!("round/{exec}"), 1500, || {
            sess.round(exec).expect("round");
        });
    }

    // extract cost
    {
        let mut sess = rt.session(&prompt, &base).expect("session");
        bench_fn("extract/snapshot", 800, || {
            let s = sess.extract().expect("extract");
            std::hint::black_box(s.out_len);
        });
    }

    // resident vs hostloop end-to-end
    let engine = DecodeEngine::new(Runtime::new(&dir).expect("rt"));
    bench_fn("e2e/resident_state/48tok", 4000, || {
        let r = engine.generate("Q: 12+34=?\nA: ", &base).expect("gen");
        std::hint::black_box(r.tokens.len());
    });
    let mut engine_h = DecodeEngine::new(Runtime::new(&dir).expect("rt"));
    engine_h.hostloop = true;
    bench_fn("e2e/hostloop/48tok", 4000, || {
        let r = engine_h.generate("Q: 12+34=?\nA: ", &base).expect("gen");
        std::hint::black_box(r.tokens.len());
    });
}
