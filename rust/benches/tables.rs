//! End-to-end paper-table bench: a fast-n version of `mars bench all`
//! wired into `cargo bench` so the whole Table 1 pipeline is exercised by
//! the standard bench entrypoint. Full-size tables: `mars bench all`.

mod bench_util;

use bench_util::artifacts_dir;
use mars::bench::{self, BenchCtx};
use mars::engine::DecodeEngine;
use mars::runtime::Runtime;

fn main() {
    let Some(dir) = artifacts_dir() else { return };
    println!("== paper tables (reduced n; full run: mars bench all) ==");
    let rt = Runtime::new(&dir).expect("runtime");
    let engine = DecodeEngine::new(rt);
    let mut ctx = BenchCtx::new(&engine, 4, 7);
    ctx.max_new = 48;
    ctx.out_dir = std::path::PathBuf::from("results/bench_quick");
    bench::table1(&ctx).expect("table1");
    bench::table6(&ctx).expect("table6");
    bench::perf(&ctx, &dir).expect("perf");
}
