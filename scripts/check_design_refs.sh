#!/usr/bin/env bash
# Documentation cross-reference check (run by the CI docs job):
# every `§N[.M]` reference inside rust doc comments must resolve to a
# DESIGN.md heading, so module docs can't drift from the layer map.
# Named references like `§Perf` are prose, not headings, and are ignored.
set -euo pipefail
cd "$(dirname "$0")/.."
python3 - <<'EOF'
import pathlib
import re
import sys

design = pathlib.Path("DESIGN.md").read_text()
headings = set(re.findall(r"^#+\s+§([0-9]+(?:\.[0-9]+)?)\b", design, re.M))
bad = []
for path in sorted(pathlib.Path("rust/src").rglob("*.rs")):
    for line_no, line in enumerate(path.read_text().splitlines(), 1):
        stripped = line.lstrip()
        if not (stripped.startswith("//!") or stripped.startswith("///")):
            continue
        for ref in re.findall(r"§([0-9]+(?:\.[0-9]+)?)", line):
            if ref not in headings:
                bad.append(
                    f"{path}:{line_no}: §{ref} is not a DESIGN.md heading"
                )
print("DESIGN.md § headings:", ", ".join(sorted(headings)))
if bad:
    print("\n".join(bad))
    sys.exit(1)
print("ok: every § reference in rust doc comments resolves")
EOF
