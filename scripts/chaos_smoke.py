#!/usr/bin/env python3
"""Chaos smoke checker for `mars serve` under fault injection
(DESIGN.md §13).

Drives generations against a server started with a deterministic
`--fault-plan` (typically `dispatch=1.0,rebuild=1.0,seed=N,only=0`
over two replicas, so replica 0 is killed early and the router must
fail over) and checks the failure-semantics acceptance bar from the
client's seat:

* every request reaches exactly one terminal reply — `"ok": true`, a
  typed retriable error (`"retriable": true`), or a busy rejection
  (`"busy": true` with `"retry_after_ms"`); nothing hangs (a hard
  per-request wall deadline aborts the run with a named error);
* at least one request succeeds even with a replica down (failover);
* a request carrying `"deadline_ms": 1` still replies `"ok": true`
  with partial text and `"deadline_exceeded": true` — a truncation,
  not a failure;
* the final `{"cmd": "metrics"}` snapshot is written to --out for the
  CI jq gate (failure counters + per-replica health).

Stdlib only (CI runs it bare). Exit 0 on success; the first violation
is printed to stderr and exits 1.
"""

import argparse
import json
import socket
import sys
import time


def die(msg: str) -> None:
    print(f"chaos_smoke: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def rpc(addr: str, payload: dict, timeout: float = 120.0) -> dict:
    """One line-JSON request/reply round trip on a fresh connection.

    The socket timeout is the client-side wall deadline: a server that
    wedges instead of replying fails the smoke with a named error
    rather than hanging the CI job.
    """
    host, port = addr.rsplit(":", 1)
    try:
        with socket.create_connection((host, int(port)), timeout=timeout) as s:
            s.sendall((json.dumps(payload) + "\n").encode())
            buf = b""
            while not buf.endswith(b"\n"):
                chunk = s.recv(65536)
                if not chunk:
                    die(f"connection closed mid-reply to {payload}")
                buf += chunk
    except socket.timeout:
        die(f"client wall deadline ({timeout:.0f}s) hit waiting on {payload}")
    return json.loads(buf.decode())


def wait_ready(addr: str, timeout_s: float) -> None:
    deadline = time.monotonic() + timeout_s
    last = "never connected"
    while time.monotonic() < deadline:
        try:
            if rpc(addr, {"cmd": "ping"}, timeout=2.0).get("pong"):
                return
            last = "ping reply without pong"
        except OSError as e:
            last = str(e)
        time.sleep(0.25)
    die(f"server at {addr} not ready after {timeout_s:.0f}s ({last})")


def classify(reply: dict) -> str:
    """Bucket a reply into its terminal class, or die on a non-answer."""
    if reply.get("busy"):
        if not isinstance(reply.get("retry_after_ms"), int):
            die(f"busy reply without retry_after_ms: {reply}")
        return "busy"
    if reply.get("ok"):
        return "ok"
    if reply.get("error"):
        return "retriable" if reply.get("retriable") else "hard"
    die(f"non-terminal reply shape: {reply}")
    raise AssertionError  # unreachable; die() exits


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--addr", required=True, help="line-JSON TCP host:port")
    ap.add_argument("--requests", type=int, default=12,
                    help="faulted generations to drive")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--out", help="write the final metrics snapshot here")
    ap.add_argument("--wall", type=float, default=120.0,
                    help="per-request client wall deadline, seconds")
    ap.add_argument("--timeout", type=float, default=120.0,
                    help="server readiness timeout, seconds")
    ap.add_argument("--shutdown", action="store_true",
                    help='send {"cmd": "shutdown"} after the checks pass')
    args = ap.parse_args()

    wait_ready(args.addr, args.timeout)

    counts = {"ok": 0, "retriable": 0, "busy": 0, "hard": 0}
    for i in range(args.requests):
        reply = rpc(args.addr, {
            "id": i + 1,
            "prompt": f"chaos smoke {i}",
            "policy": "mars:0.9",
            "max_new": args.max_new,
            "seed": i + 1,
        }, timeout=args.wall)
        counts[classify(reply)] += 1
    total = sum(counts.values())
    if total != args.requests:
        die(f"lost replies: {total} terminal of {args.requests} sent")
    if counts["hard"]:
        die(f"{counts['hard']} hard (non-retriable) errors: {counts}")
    if counts["ok"] < 1:
        die(f"no request succeeded — failover broken: {counts}")
    print(f"chaos_smoke: terminal accounting OK: {counts}")

    # deadline semantics: with the dead replica skipped, a 1 ms budget
    # must truncate, not fail — partial text plus the marker field
    reply = rpc(args.addr, {
        "id": 9001,
        "prompt": "deadline probe",
        "policy": "mars:0.9",
        "max_new": 2048,
        "seed": 1,
        "deadline_ms": 1,
    }, timeout=args.wall)
    kind = classify(reply)
    if kind == "ok":
        if reply.get("deadline_exceeded") is not True:
            die(f"1ms-deadline reply lacks deadline_exceeded: {reply}")
        if reply.get("tokens", 2048) >= 2048:
            die(f"deadline did not truncate: {reply.get('tokens')} tokens")
        print("chaos_smoke: deadline truncation OK "
              f"({reply.get('tokens')} tokens)")
    elif kind != "retriable":
        die(f"deadline probe reached a non-terminal class {kind}: {reply}")

    snapshot = rpc(args.addr, {"cmd": "metrics"})
    if not isinstance(snapshot.get("failures"), dict):
        die(f'snapshot carries no "failures" object: {list(snapshot)}')
    if not isinstance(snapshot.get("health"), dict):
        die(f'snapshot carries no "health" object: {list(snapshot)}')
    if args.out:
        with open(args.out, "w") as f:
            json.dump(snapshot, f)

    if args.shutdown:
        rpc(args.addr, {"cmd": "shutdown"})
    print("chaos_smoke: PASS")


if __name__ == "__main__":
    main()
