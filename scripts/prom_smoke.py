#!/usr/bin/env python3
"""Prometheus-exposition smoke checker for `mars serve` (DESIGN.md §12).

Drives a few probe generations over the line-JSON TCP port, scrapes the
exposition — both the `{"cmd": "prom"}` RPC and, when --prom-url is
given, the `--prom-addr` HTTP endpoint — and validates the subset of
text format 0.0.4 the server emits:

* every non-comment line parses as ``name{labels} value``;
* every sample belongs to a ``# TYPE``-declared family, and only
  histogram families use the ``_bucket`` / ``_sum`` / ``_count``
  suffixes;
* every histogram label-set carries cumulative ``le`` buckets that are
  monotone non-decreasing, end at ``le="+Inf"``, and agree with the
  family's ``_count``; ``_sum`` is present;
* the core request families exist, and with --expect-margin the
  margin-by-outcome histogram (``mars_margin{policy,method,outcome}``)
  carries all three outcomes.

Stdlib only (CI runs it bare). Exit 0 on success; the first violation
is printed to stderr and exits 1.
"""

import argparse
import json
import math
import re
import socket
import sys
import time
import urllib.request

SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"  # metric name
    r"(?:\{(.*)\})?"  # optional label body
    r" (-?(?:[0-9]*\.?[0-9]+(?:[eE][+-]?[0-9]+)?)|[+-]Inf|NaN)$"
)
LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')
TYPE_RE = re.compile(
    r"^# TYPE ([a-zA-Z_:][a-zA-Z0-9_:]*) (counter|gauge|histogram)$"
)


def die(msg: str) -> None:
    print(f"prom_smoke: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def rpc(addr: str, payload: dict, timeout: float = 60.0) -> dict:
    """One line-JSON request/reply round trip on a fresh connection."""
    host, port = addr.rsplit(":", 1)
    with socket.create_connection((host, int(port)), timeout=timeout) as s:
        s.sendall((json.dumps(payload) + "\n").encode())
        buf = b""
        while not buf.endswith(b"\n"):
            chunk = s.recv(65536)
            if not chunk:
                die(f"connection closed mid-reply to {payload}")
            buf += chunk
    return json.loads(buf.decode())


def wait_ready(addr: str, timeout_s: float) -> None:
    deadline = time.monotonic() + timeout_s
    last = "never connected"
    while time.monotonic() < deadline:
        try:
            if rpc(addr, {"cmd": "ping"}, timeout=2.0).get("pong"):
                return
            last = "ping reply without pong"
        except OSError as e:
            last = str(e)
        time.sleep(0.25)
    die(f"server at {addr} not ready after {timeout_s:.0f}s ({last})")


def parse_value(raw: str) -> float:
    if raw == "+Inf":
        return math.inf
    if raw == "-Inf":
        return -math.inf
    return float(raw)  # NaN parses fine


def parse_labels(body: str) -> dict:
    labels = dict(LABEL_RE.findall(body or ""))
    # the label body must be nothing but well-formed pairs + separators
    leftovers = LABEL_RE.sub("", body or "").replace(",", "").strip()
    if leftovers:
        die(f"malformed label body: {{{body}}}")
    return labels


def parse_exposition(text: str, origin: str):
    """Return (families, samples): declared types and parsed samples."""
    families = {}
    samples = []  # (name, labels, value)
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP"):
            continue
        if line.startswith("# TYPE"):
            m = TYPE_RE.match(line)
            if not m:
                die(f"{origin}:{lineno}: bad TYPE line: {line!r}")
            name, kind = m.groups()
            if name in families:
                die(f"{origin}:{lineno}: duplicate TYPE for {name}")
            families[name] = kind
            continue
        if line.startswith("#"):
            die(f"{origin}:{lineno}: unknown comment form: {line!r}")
        m = SAMPLE_RE.match(line)
        if not m:
            die(f"{origin}:{lineno}: unparseable sample: {line!r}")
        name, label_body, raw = m.groups()
        samples.append((name, parse_labels(label_body), parse_value(raw)))
    return families, samples


def family_of(name: str, families: dict) -> str:
    """Resolve a sample name to its declared family, or die."""
    for suffix in ("_bucket", "_sum", "_count"):
        if name.endswith(suffix):
            base = name[: -len(suffix)]
            if families.get(base) == "histogram":
                return base
    if name in families:
        if families[name] == "histogram":
            die(f"histogram {name} sampled without a suffix")
        return name
    die(f"sample {name} has no # TYPE declaration")
    raise AssertionError  # unreachable; die() exits


def check_histograms(families: dict, samples: list) -> None:
    """Cumulative-bucket discipline per histogram label set."""
    by_series = {}  # (family, frozen labels sans le) -> dict
    for name, labels, value in samples:
        fam = family_of(name, families)
        if families[fam] != "histogram":
            if math.isnan(value):
                die(f"{name}: NaN sample")
            if families[fam] == "counter" and value < 0:
                die(f"{name}: negative counter {value}")
            continue
        key_labels = {k: v for k, v in labels.items() if k != "le"}
        series = by_series.setdefault(
            (fam, tuple(sorted(key_labels.items()))),
            {"buckets": [], "sum": None, "count": None},
        )
        if name.endswith("_bucket"):
            if "le" not in labels:
                die(f"{name}: bucket sample without an le label")
            series["buckets"].append((parse_value(labels["le"]), value))
        elif name.endswith("_sum"):
            series["sum"] = value
        elif name.endswith("_count"):
            series["count"] = value
    if not by_series and any(k == "histogram" for k in families.values()):
        die("histogram families declared but no bucket samples found")
    for (fam, key), series in by_series.items():
        where = f"{fam}{{{dict(key)}}}"
        buckets = series["buckets"]
        if not buckets:
            die(f"{where}: no _bucket samples")
        if series["sum"] is None or series["count"] is None:
            die(f"{where}: missing _sum or _count")
        les = [le for le, _ in buckets]
        if les != sorted(les):
            die(f"{where}: le bounds out of order: {les}")
        if les[-1] != math.inf:
            die(f"{where}: last bucket is not le=\"+Inf\"")
        counts = [c for _, c in buckets]
        if any(b < a for a, b in zip(counts, counts[1:])):
            die(f"{where}: cumulative bucket counts decrease: {counts}")
        if counts[-1] != series["count"]:
            die(
                f"{where}: +Inf bucket {counts[-1]} != _count "
                f"{series['count']}"
            )


def check_exposition(text: str, origin: str, expect_margin: bool) -> None:
    families, samples = parse_exposition(text, origin)
    check_histograms(families, samples)
    for required in ("mars_requests_ok", "mars_uptime_seconds", "mars_ttft_ms"):
        if required not in families:
            die(f"{origin}: required family {required} missing")
    ok = sum(v for n, _, v in samples if n == "mars_requests_ok")
    if ok < 1:
        die(f"{origin}: mars_requests_ok is {ok}, expected >= 1")
    if expect_margin:
        if families.get("mars_margin") != "histogram":
            die(f"{origin}: mars_margin histogram missing")
        outcomes = {
            labels.get("outcome")
            for n, labels, _ in samples
            if n == "mars_margin_count"
        }
        missing = {"exact", "relaxed", "reject"} - outcomes
        if missing:
            die(f"{origin}: mars_margin outcomes missing: {sorted(missing)}")
        decided = sum(
            v for n, labels, v in samples if n == "mars_margin_count"
        )
        if decided < 1:
            die(f"{origin}: mars_margin recorded no verify decisions")
    print(f"prom_smoke: {origin}: {len(families)} families, "
          f"{len(samples)} samples OK")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--addr", required=True, help="line-JSON TCP host:port")
    ap.add_argument("--prom-url", help="HTTP exposition URL to also scrape")
    ap.add_argument("--requests", type=int, default=2,
                    help="probe generations to drive before scraping")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--expect-margin", action="store_true",
                    help="require the margin-by-outcome histogram")
    ap.add_argument("--timeout", type=float, default=120.0,
                    help="server readiness timeout, seconds")
    ap.add_argument("--shutdown", action="store_true",
                    help='send {"cmd": "shutdown"} after the checks pass')
    args = ap.parse_args()

    wait_ready(args.addr, args.timeout)
    for i in range(args.requests):
        reply = rpc(args.addr, {
            "id": i + 1,
            "prompt": f"telemetry smoke {i}",
            "policy": "mars:0.9",
            "max_new": args.max_new,
            "seed": i + 1,
            "probe": True,
        })
        if not reply.get("ok"):
            die(f"generation {i + 1} failed: {reply.get('error')}")

    via_rpc = rpc(args.addr, {"cmd": "prom"}).get("prom")
    if not isinstance(via_rpc, str):
        die('{"cmd": "prom"} reply carries no "prom" string')
    check_exposition(via_rpc, "rpc", args.expect_margin)

    if args.prom_url:
        with urllib.request.urlopen(args.prom_url, timeout=30) as resp:
            ctype = resp.headers.get("Content-Type", "")
            if not ctype.startswith("text/plain"):
                die(f"http: Content-Type {ctype!r} is not text/plain")
            if "version=0.0.4" not in ctype:
                die(f"http: Content-Type {ctype!r} lacks version=0.0.4")
            body = resp.read().decode()
        check_exposition(body, "http", args.expect_margin)

    if args.shutdown:
        rpc(args.addr, {"cmd": "shutdown"})
    print("prom_smoke: PASS")


if __name__ == "__main__":
    main()
