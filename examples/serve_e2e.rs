//! End-to-end serving driver (the validation run recorded in
//! EXPERIMENTS.md): starts the router + replicas + TCP server, smokes the
//! streaming/pipelined wire protocol (client ids, per-round deltas),
//! drives a mixed open-loop workload of batched requests across all five
//! task families and both verification modes, and reports
//! latency/throughput.
//!
//! ```sh
//! cargo run --release --example serve_e2e -- [n_requests] [replicas]
//! ```

use std::sync::Arc;

use mars::coordinator::router::{Router, RouterPolicy};
use mars::coordinator::scheduler;
use mars::coordinator::server;
use mars::datasets::{dataset, Task};
use mars::engine::{GenParams, SpecMethod};
use mars::runtime::Artifacts;
use mars::verify::VerifyPolicy;
use mars::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(40);
    let replicas: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1);

    let dir = Artifacts::default_dir();
    if !Artifacts::available(&dir) {
        eprintln!("artifacts not found — run `make artifacts`");
        return Ok(());
    }

    println!("starting router with {replicas} replica(s)...");
    let router = Arc::new(Router::start(
        &dir,
        replicas,
        4,
        false,
        RouterPolicy::LeastLoaded,
        mars::cache::CacheConfig::default(),
        1,
    )?);

    // TCP smoke: prove the wire protocol works end to end
    let handle = server::serve(router.clone(), "127.0.0.1:0")?;
    let addr = handle.addr.to_string();
    let pong = server::client_roundtrip(&addr, r#"{"cmd": "ping"}"#)?;
    println!("server up on {addr}, ping -> {}", pong.to_string_json());
    let wire = server::client_roundtrip(
        &addr,
        "{\"id\": 1, \"prompt\": \"Q: 6+7=?\\nA: \", \
         \"method\": \"eagle_tree\", \"policy\": {\"mars\": {\"theta\": 0.9}}, \
         \"max_new\": 16, \"seed\": 3}",
    )?;
    println!("wire request -> {}", wire.to_string_json());

    // streaming: deltas arrive per verify round, before the final reply
    let (deltas, fin) = server::client_stream(
        &addr,
        "{\"id\": 2, \"prompt\": \"Q: 9+5=?\\nA: \", \"stream\": true, \
         \"policy\": \"mars:0.9\", \"max_new\": 24, \"seed\": 5}",
    )?;
    let joined: String = deltas
        .iter()
        .filter_map(|d| d.get("delta").and_then(|s| s.as_str()))
        .collect();
    println!(
        "stream request -> {} delta line(s), concatenated == final text: {}\n",
        deltas.len(),
        Some(joined.as_str()) == fin.get("text").and_then(|t| t.as_str())
    );

    // mixed workload: all tasks, alternating strict / MARS verification
    let mut prompts = Vec::new();
    for i in 0..n_requests {
        let task = Task::all()[i % Task::all().len()];
        let ex = &dataset(task, 1, 1000 + i as u64)[0];
        // alternate the verification policy across the workload so the
        // per-policy metrics breakout has something to show
        let policy = match i % 4 {
            0 => VerifyPolicy::Mars { theta: 0.9 },
            1 => VerifyPolicy::Strict,
            2 => VerifyPolicy::TopK { k: 2, eps: 0.1 },
            _ => VerifyPolicy::Entropy { h_max: 1.5 },
        };
        // rotate the drafting method too, so the per-method metrics
        // breakout has something to show alongside the per-policy one
        let method = match i % 3 {
            0 => SpecMethod::default(),
            1 => SpecMethod::Sps { k: 6 },
            _ => SpecMethod::Pld { min_ngram: 2, max_ngram: 4, k: 7 },
        };
        let params = GenParams {
            method,
            policy,
            temperature: 1.0,
            max_new: 64,
            seed: i as u64,
            ..GenParams::default()
        };
        prompts.push((ex.prompt.clone(), params));
    }

    println!("driving {n_requests} requests (open loop, ~20 req/s)...");
    let t0 = std::time::Instant::now();
    let responses = scheduler::drive_open_loop(&router, &prompts, 20.0, 42);
    let wall = t0.elapsed().as_secs_f64();

    let mut lat = Summary::new();
    let mut tau_mars = Summary::new();
    let mut tau_strict = Summary::new();
    let mut tokens = 0usize;
    let mut errors = 0usize;
    for r in responses.iter() {
        if !r.ok {
            errors += 1;
            continue;
        }
        tokens += r.tokens;
        lat.push((r.decode_seconds + r.prefill_seconds) * 1e3);
        if r.policy.starts_with("strict") {
            tau_strict.push(r.tau);
        } else {
            tau_mars.push(r.tau);
        }
    }

    println!("\n== serve_e2e results ==");
    println!("requests: {} ok, {} errors", responses.len() - errors, errors);
    println!("wall time: {wall:.2}s");
    println!("throughput: {:.1} tok/s, {:.2} req/s",
        tokens as f64 / wall, (responses.len() - errors) as f64 / wall);
    println!(
        "request latency ms: p50={:.0} p99={:.0} mean={:.0}",
        lat.p50(),
        lat.p99(),
        lat.mean()
    );
    println!(
        "tau: relaxed-policy={:.2} strict={:.2} (relaxed verification \
         accepts more per round)",
        tau_mars.mean(),
        tau_strict.mean()
    );
    println!(
        "router metrics: {}",
        router.metrics.snapshot_json().to_string_json()
    );
    Ok(())
}
