//! θ-threshold ablation (Figure 3 shape, small-n): sweeps the MARS
//! logit-ratio threshold and prints the speedup/accuracy trade-off.
//!
//! ```sh
//! cargo run --release --example ablation_theta -- [n_examples]
//! ```

use mars::bench::BenchCtx;
use mars::datasets::Task;
use mars::engine::{DecodeEngine, GenParams, SpecMethod};
use mars::runtime::{Artifacts, Runtime};
use mars::verify::VerifyPolicy;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let dir = Artifacts::default_dir();
    if !Artifacts::available(&dir) {
        eprintln!("artifacts not found — run `make artifacts`");
        return Ok(());
    }
    let engine = DecodeEngine::new(Runtime::new(&dir)?);
    let ctx = BenchCtx::new(&engine, n, 7);

    let task = Task::Arith;
    let base = ctx.baseline(task, 1.0)?;
    println!(
        "baseline (AR): acc={:.3} {:.1} tok/s\n",
        base.quality.accuracy, base.mean_tok_per_s
    );
    println!("theta | speedup(sim) | speedup(wall) | tau  | accuracy | relaxed");
    println!("------+--------------+---------------+------+----------+--------");
    for theta in [0.80f32, 0.84, 0.88, 0.90, 0.92, 0.96, 0.995] {
        let p = GenParams {
            method: SpecMethod::default(),
            policy: VerifyPolicy::Mars { theta },
            temperature: 1.0,
            max_new: 96,
            ..GenParams::default()
        };
        let e = ctx.run_task(task, &p)?;
        println!(
            "{theta:.3} | {:>11.2}x | {:>12.2}x | {:>4.2} | {:>8.3} | {:>6.0}",
            e.speedup_sim(&base),
            e.speedup_wall(&base),
            e.tau,
            e.quality.accuracy,
            e.relaxed_total
        );
    }
    println!(
        "\nExpected shape (paper Fig. 3): speedup decreases monotonically \
         with theta; accuracy peaks near theta = 0.9."
    );
    Ok(())
}
