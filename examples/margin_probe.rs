//! Margin-probe demo (Figures 1 & 4 shape): runs probe-enabled decodes
//! and summarizes the (z1, z2) statistics MARS exploits — top-1 logit
//! positivity, the logit-ratio distribution, and where relaxed
//! acceptances land.
//!
//! ```sh
//! cargo run --release --example margin_probe
//! ```

use mars::datasets::{dataset, Task};
use mars::engine::{DecodeEngine, GenParams, SpecMethod};
use mars::runtime::{Artifacts, Runtime};
use mars::verify::{AcceptFlag, VerifyPolicy};

fn main() -> anyhow::Result<()> {
    let dir = Artifacts::default_dir();
    if !Artifacts::available(&dir) {
        eprintln!("artifacts not found — run `make artifacts`");
        return Ok(());
    }
    let engine = DecodeEngine::new(Runtime::new(&dir)?);

    let mut entries = Vec::new();
    for (i, &task) in Task::all().iter().enumerate() {
        for (j, ex) in dataset(task, 4, 99).iter().enumerate() {
            let p = GenParams {
                method: SpecMethod::default(),
                policy: VerifyPolicy::Mars { theta: 0.9 },
                probe: true,
                temperature: 1.0,
                max_new: 64,
                seed: (i * 10 + j) as u64,
                ..GenParams::default()
            };
            let r = engine.generate(&ex.prompt, &p)?;
            if let Some(probe) = r.probe {
                entries.extend(probe.entries);
            }
        }
    }

    let n = entries.len().max(1);
    let neg = entries.iter().filter(|e| e.z1 < 0.0).count();
    println!("probe entries: {n}");
    println!(
        "top-1 logit negative fraction: {:.2}% (paper Fig. 4a: 0.0%)",
        100.0 * neg as f64 / n as f64
    );

    let mut in_zone = 0;
    let mut relaxed_in_zone = 0;
    let mut relaxed_total = 0;
    for e in &entries {
        let r = if e.z1 > 0.0 && e.z2 > 0.0 { e.z2 / e.z1 } else { 0.0 };
        if e.flag == AcceptFlag::Relaxed {
            relaxed_total += 1;
        }
        if r > 0.9 {
            in_zone += 1;
            if e.flag == AcceptFlag::Relaxed {
                relaxed_in_zone += 1;
            }
        }
    }
    println!(
        "low-margin zone (r > 0.9): {:.1}% of decisions",
        100.0 * in_zone as f64 / n as f64
    );
    println!(
        "relaxed acceptances: {relaxed_total} total, {relaxed_in_zone} in \
         zone ({}% — should be 100%: MARS only relaxes above theta)",
        if relaxed_total > 0 {
            100 * relaxed_in_zone / relaxed_total
        } else {
            0
        }
    );

    // metric decoupling (Fig. 1c): logit ratio high, prob ratio anywhere
    let mut bands = [0usize; 5];
    for e in entries.iter().filter(|e| e.flag == AcceptFlag::Relaxed) {
        let pr = (e.z2 - e.z1).exp();
        let b = ((pr * 5.0) as usize).min(4);
        bands[b] += 1;
    }
    println!("\nrelaxed accepts by p2/p1 band (metric decoupling, Fig. 1c):");
    for (i, c) in bands.iter().enumerate() {
        println!(
            "  p2/p1 {:.1}-{:.1}: {c}",
            i as f64 * 0.2,
            (i + 1) as f64 * 0.2
        );
    }
    Ok(())
}
