//! Quickstart: load the artifacts, generate with vanilla AR and with MARS,
//! and compare τ / speed. Run after `make artifacts && cargo build`:
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use mars::engine::{DecodeEngine, GenParams, SpecMethod};
use mars::runtime::{Artifacts, Runtime};
use mars::verify::VerifyPolicy;

fn main() -> anyhow::Result<()> {
    let dir = Artifacts::default_dir();
    if !Artifacts::available(&dir) {
        eprintln!("artifacts not found at {} — run `make artifacts`", dir.display());
        return Ok(());
    }
    let rt = Runtime::new(&dir)?;
    println!("runtime up ({:.1}s compile)", rt.compile_seconds);
    let engine = DecodeEngine::new(rt);

    let prompt = "Q: 37+58=?\nA: ";
    println!("prompt: {prompt:?}\n");

    // vanilla autoregressive baseline (the paper's 1.00x)
    let ar = engine.generate(
        prompt,
        &GenParams {
            method: SpecMethod::Ar,
            temperature: 1.0,
            max_new: 32,
            seed: 1,
            ..GenParams::default()
        },
    )?;
    println!("AR        : {:?}", ar.text.trim());
    println!(
        "            {:.1} tok/s, {} rounds",
        ar.tok_per_sec(),
        ar.snapshot.rounds
    );

    // EAGLE-style speculative decoding, strict verification
    let strict = engine.generate(
        prompt,
        &GenParams {
            method: SpecMethod::default(),
            policy: VerifyPolicy::Strict,
            temperature: 1.0,
            max_new: 32,
            seed: 1,
            ..GenParams::default()
        },
    )?;
    println!("EAGLE     : {:?}", strict.text.trim());
    println!(
        "            {:.1} tok/s, tau={:.2}",
        strict.tok_per_sec(),
        strict.tau()
    );

    // + MARS margin-aware verification (the paper's contribution)
    let mars = engine.generate(
        prompt,
        &GenParams {
            method: SpecMethod::default(),
            policy: VerifyPolicy::Mars { theta: 0.9 },
            temperature: 1.0,
            max_new: 32,
            seed: 1,
            ..GenParams::default()
        },
    )?;
    println!("MARS      : {:?}", mars.text.trim());
    println!(
        "            {:.1} tok/s, tau={:.2}, relaxed tie-breaks={}",
        mars.tok_per_sec(),
        mars.tau(),
        mars.snapshot.relaxed_accepts
    );

    println!(
        "\nspeedup vs AR: EAGLE {:.2}x, MARS {:.2}x (wall-clock)",
        strict.tok_per_sec() / ar.tok_per_sec(),
        mars.tok_per_sec() / ar.tok_per_sec()
    );
    Ok(())
}
